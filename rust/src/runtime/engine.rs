//! The engine service: PJRT clients on dedicated threads, executing the
//! compiled artifacts for any rank that asks.
//!
//! The PJRT backend itself (the `xla` crate) is only available in builds
//! with the `pjrt` feature and a vendored `xla` dependency; the default
//! offline build compiles a stub backend that reports unavailability at
//! startup, and every caller falls back to the bit-faithful native compute
//! paths (`apps::compute`). The manifest/spec plumbing and the engine
//! service protocol are identical either way, so the fallback is exercised
//! by the same tests.

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use super::value::{TensorSpec, Value};

/// Engine-layer error (`anyhow` is unavailable in the offline image).
#[derive(Debug, Clone)]
pub struct EngineError(String);

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for EngineError {}

pub type Result<T> = std::result::Result<T, EngineError>;

fn err(msg: impl Into<String>) -> EngineError {
    EngineError(msg.into())
}

/// One kernel's manifest entry.
#[derive(Clone, Debug)]
pub struct KernelSpec {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn parse_manifest(text: &str) -> Result<Vec<KernelSpec>> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        // Format: `name | in: spec spec ... | out: spec spec ...`
        let mut parts = line.split('|');
        let name = parts
            .next()
            .ok_or_else(|| err("manifest line missing name"))?
            .trim()
            .to_string();
        let ins = parts
            .next()
            .ok_or_else(|| err(format!("manifest `{name}`: missing `in:` section")))?
            .trim();
        let outs = parts
            .next()
            .ok_or_else(|| err(format!("manifest `{name}`: missing `out:` section")))?
            .trim();
        let parse_list = |s: &str, prefix: &str| -> Result<Vec<TensorSpec>> {
            s.strip_prefix(prefix)
                .ok_or_else(|| err(format!("manifest `{name}`: expected `{prefix}` prefix")))?
                .split_whitespace()
                .map(|t| TensorSpec::parse(t).ok_or_else(|| err(format!("bad spec {t}"))))
                .collect()
        };
        out.push(KernelSpec {
            inputs: parse_list(ins, "in:")?,
            outputs: parse_list(outs, "out:")?,
            name,
        });
    }
    Ok(out)
}

struct Request {
    kernel: String,
    args: Vec<Value>,
    reply: mpsc::Sender<std::result::Result<Vec<Value>, String>>,
}

/// Cloneable, thread-safe handle to the engine pool.
#[derive(Clone)]
pub struct ComputeEngine {
    inner: Arc<EngineInner>,
}

struct EngineInner {
    txs: Vec<mpsc::Sender<Request>>,
    next: AtomicUsize,
    specs: HashMap<String, KernelSpec>,
}

impl ComputeEngine {
    /// Start `nthreads` engine threads, each compiling every artifact in
    /// `dir`. Fails fast if the directory or manifest is missing — or, in a
    /// default (non-`pjrt`) build, always — and callers fall back to native
    /// compute (see `apps::compute`).
    pub fn start(dir: impl AsRef<Path>, nthreads: usize) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .map_err(|e| err(format!("no manifest in {}: {e}", dir.display())))?;
        let specs_list = parse_manifest(&manifest)?;
        let specs: HashMap<String, KernelSpec> = specs_list
            .iter()
            .map(|s| (s.name.clone(), s.clone()))
            .collect();

        let mut txs = Vec::new();
        let mut ready_rxs = Vec::new();
        for tid in 0..nthreads.max(1) {
            let (tx, rx) = mpsc::channel::<Request>();
            let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
            let dir2 = dir.clone();
            let specs2 = specs_list.clone();
            std::thread::Builder::new()
                .name(format!("pjrt-engine-{tid}"))
                .spawn(move || backend::engine_thread(dir2, specs2, rx, ready_tx))
                .expect("spawn engine");
            txs.push(tx);
            ready_rxs.push(ready_rx);
        }
        // Wait for compilation to finish on every engine.
        for rx in ready_rxs {
            rx.recv()
                .map_err(|_| err("engine thread died during startup"))?
                .map_err(err)?;
        }
        Ok(Self {
            inner: Arc::new(EngineInner {
                txs,
                next: AtomicUsize::new(0),
                specs,
            }),
        })
    }

    /// Start from the conventional `artifacts/` dir next to the repo root.
    pub fn start_default(nthreads: usize) -> Result<Self> {
        Self::start(Self::default_dir(), nthreads)
    }

    /// `$PARTREPER_ARTIFACTS` or `<crate root>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("PARTREPER_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    pub fn kernels(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.specs.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn spec(&self, kernel: &str) -> Option<&KernelSpec> {
        self.inner.specs.get(kernel)
    }

    /// Execute `kernel` with `args`, blocking until the result is back.
    /// Round-robins across engine threads so concurrent ranks overlap.
    pub fn run(&self, kernel: &str, args: Vec<Value>) -> Result<Vec<Value>> {
        let spec = self
            .inner
            .specs
            .get(kernel)
            .ok_or_else(|| err(format!("unknown kernel {kernel}")))?;
        if spec.inputs.len() != args.len() {
            return Err(err(format!(
                "{kernel}: expected {} args, got {}",
                spec.inputs.len(),
                args.len()
            )));
        }
        for (i, (s, a)) in spec.inputs.iter().zip(&args).enumerate() {
            if s.numel() != a.len() {
                return Err(err(format!(
                    "{kernel}: arg {i} numel {} != spec {}",
                    a.len(),
                    s.numel()
                )));
            }
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let idx = self.inner.next.fetch_add(1, Ordering::Relaxed) % self.inner.txs.len();
        self.inner.txs[idx]
            .send(Request {
                kernel: kernel.to_string(),
                args,
                reply: reply_tx,
            })
            .map_err(|_| err("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| err("engine dropped reply"))?
            .map_err(err)
    }
}

/// Stub backend for the default offline build: reports unavailability at
/// readiness time, so `ComputeEngine::start` fails fast and callers take
/// the native compute path.
#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::{KernelSpec, Request};
    use std::path::PathBuf;
    use std::sync::mpsc;

    pub(super) fn engine_thread(
        _dir: PathBuf,
        _specs: Vec<KernelSpec>,
        _rx: mpsc::Receiver<Request>,
        ready: mpsc::Sender<Result<(), String>>,
    ) {
        let _ = ready.send(Err(
            "PJRT backend not compiled in (build with --features pjrt and a vendored \
             `xla` crate); using native compute"
                .to_string(),
        ));
    }
}

/// Real PJRT backend (requires the vendored `xla` crate).
#[cfg(feature = "pjrt")]
mod backend {
    use super::{err, KernelSpec, Request, Result};
    use crate::runtime::value::{DtypeTag, Value};
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::mpsc;

    pub(super) fn engine_thread(
        dir: PathBuf,
        specs: Vec<KernelSpec>,
        rx: mpsc::Receiver<Request>,
        ready: mpsc::Sender<std::result::Result<(), String>>,
    ) {
        // Build the client + compile everything; report readiness.
        let built = (|| -> Result<(
            xla::PjRtClient,
            HashMap<String, (xla::PjRtLoadedExecutable, KernelSpec)>,
        )> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| err(format!("pjrt cpu client: {e:?}")))?;
            let mut exes = HashMap::new();
            for spec in specs {
                let path = dir.join(format!("{}.hlo.txt", spec.name));
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| err(format!("load {}: {e:?}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| err(format!("compile {}: {e:?}", spec.name)))?;
                exes.insert(spec.name.clone(), (exe, spec));
            }
            Ok((client, exes))
        })();

        let (_client, exes) = match built {
            Ok(v) => {
                let _ = ready.send(Ok(()));
                v
            }
            Err(e) => {
                let _ = ready.send(Err(e.to_string()));
                return;
            }
        };

        while let Ok(req) = rx.recv() {
            let result = execute_one(&exes, &req.kernel, &req.args);
            let _ = req.reply.send(result.map_err(|e| e.to_string()));
        }
    }

    fn execute_one(
        exes: &HashMap<String, (xla::PjRtLoadedExecutable, KernelSpec)>,
        kernel: &str,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let (exe, spec) = exes
            .get(kernel)
            .ok_or_else(|| err(format!("kernel {kernel} not compiled")))?;

        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|v| -> Result<xla::Literal> {
                let lit = match v {
                    Value::F32 { data, dims } => {
                        let l = xla::Literal::vec1(data.as_slice());
                        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                        l.reshape(&dims).map_err(|e| err(format!("reshape: {e:?}")))?
                    }
                    Value::I32 { data, dims } => {
                        let l = xla::Literal::vec1(data.as_slice());
                        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                        l.reshape(&dims).map_err(|e| err(format!("reshape: {e:?}")))?
                    }
                };
                Ok(lit)
            })
            .collect::<Result<_>>()?;

        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| err(format!("execute {kernel}: {e:?}")))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| err(format!("to_literal: {e:?}")))?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-output.
        let parts = tuple
            .to_tuple()
            .map_err(|e| err(format!("to_tuple: {e:?}")))?;
        if parts.len() != spec.outputs.len() {
            return Err(err(format!(
                "{kernel}: expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            )));
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| -> Result<Value> {
                match ospec.dtype {
                    DtypeTag::F32 => {
                        let data = lit
                            .to_vec::<f32>()
                            .map_err(|e| err(format!("to_vec f32: {e:?}")))?;
                        Ok(Value::f32(data, &ospec.dims))
                    }
                    DtypeTag::I32 => {
                        let data = lit
                            .to_vec::<i32>()
                            .map_err(|e| err(format!("to_vec i32: {e:?}")))?;
                        Ok(Value::i32(data, &ospec.dims))
                    }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "cg_local | in: f32[9x2048] f32[2048] i32[9] | out: f32[2048] f32[] f32[]\n\
                    ep_local | in: f32[4096] f32[4096] | out: f32[3]\n";
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "cg_local");
        assert_eq!(specs[0].inputs.len(), 3);
        assert_eq!(specs[0].outputs[1].numel(), 1);
        assert_eq!(specs[1].inputs[0].dims, vec![4096]);
    }

    #[test]
    fn missing_dir_fails_fast() {
        assert!(ComputeEngine::start("/nonexistent/path", 1).is_err());
    }

    // PJRT smoke tests that need built artifacts live in
    // rust/tests/pjrt_integration.rs (they skip when artifacts/ is absent).
}
