//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` produced by
//! `python/compile/aot.py`) and execute them from the rank threads.
//!
//! The `xla` crate's `PjRtClient`/`PjRtLoadedExecutable` are `Rc`-based
//! (`!Send`), while our MPI ranks are hundreds of threads — so the runtime
//! is an **engine service**: a small pool of dedicated threads, each owning
//! one PJRT CPU client with every artifact compiled, serving execute
//! requests over channels. Ranks see a cloneable, thread-safe
//! [`ComputeEngine`] handle; Python never runs at run time.
//!
//! Interchange is HLO *text* (see `aot.py` — xla_extension 0.5.1 rejects
//! jax ≥ 0.5's 64-bit-id serialized protos; the text parser reassigns ids).

pub mod engine;
pub mod value;

pub use engine::ComputeEngine;
pub use value::Value;
