//! Typed host buffers crossing the engine-service channel.

/// A host tensor (inputs and outputs of kernel execution).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Value {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Value::F32 {
            data,
            dims: dims.to_vec(),
        }
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Value::I32 {
            data,
            dims: dims.to_vec(),
        }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Value::F32 {
            data: vec![v],
            dims: vec![],
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32 { dims, .. } | Value::I32 { dims, .. } => dims,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32 { data, .. } => data.len(),
            Value::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Value::F32 { data, .. } => data,
            other => panic!("expected F32 value, got {other:?}"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            Value::I32 { data, .. } => data,
            other => panic!("expected I32 value, got {other:?}"),
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Value::F32 { data, .. } => data,
            other => panic!("expected F32 value, got {other:?}"),
        }
    }

    pub fn into_i32(self) -> Vec<i32> {
        match self {
            Value::I32 { data, .. } => data,
            other => panic!("expected I32 value, got {other:?}"),
        }
    }

    /// Scalar f32 extract.
    pub fn to_scalar_f32(&self) -> f32 {
        let d = self.as_f32();
        assert_eq!(d.len(), 1, "not a scalar");
        d[0]
    }
}

/// Dtype tags used by the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DtypeTag {
    F32,
    I32,
}

/// One `dtype[shape]` spec from `manifest.txt` (e.g. `f32[9x2048]`).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: DtypeTag,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn parse(s: &str) -> Option<Self> {
        let (dt, rest) = s.split_once('[')?;
        let dims_str = rest.strip_suffix(']')?;
        let dtype = match dt {
            "f32" => DtypeTag::F32,
            "i32" => DtypeTag::I32,
            _ => return None,
        };
        let dims = if dims_str.is_empty() {
            vec![]
        } else {
            dims_str
                .split('x')
                .map(|d| d.parse().ok())
                .collect::<Option<Vec<usize>>>()?
        };
        Some(Self { dtype, dims })
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        let v = Value::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(v.dims(), &[2, 2]);
        assert_eq!(v.len(), 4);
        assert_eq!(v.as_f32()[3], 4.0);
        let s = Value::scalar_f32(7.5);
        assert_eq!(s.to_scalar_f32(), 7.5);
    }

    #[test]
    fn spec_parse() {
        let s = TensorSpec::parse("f32[9x2048]").unwrap();
        assert_eq!(s.dtype, DtypeTag::F32);
        assert_eq!(s.dims, vec![9, 2048]);
        assert_eq!(s.numel(), 9 * 2048);
        let sc = TensorSpec::parse("f32[]").unwrap();
        assert_eq!(sc.dims, Vec::<usize>::new());
        assert_eq!(sc.numel(), 1);
        let i = TensorSpec::parse("i32[9]").unwrap();
        assert_eq!(i.dtype, DtypeTag::I32);
        assert!(TensorSpec::parse("f64[3]").is_none());
        assert!(TensorSpec::parse("f32[3").is_none());
    }
}
