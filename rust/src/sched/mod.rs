//! Execution-mode scheduler: the `Clock`/`Executor` seam between the
//! threaded fabric and the discrete-event virtual-clock world (DESIGN.md
//! §8).
//!
//! Every blocking point in the runtime — `Fabric::wait_new_mail`, the
//! rendezvous gate behind `SendHandle`, the request engine's park loop,
//! OMPI consensus parking, the monitor's detect tick, the fault
//! injector's Weibull sleeps — is already a *bounded poll*: park for a
//! tick, re-check a predicate, repeat. [`Sched`] virtualizes exactly
//! that tick and nothing else:
//!
//! * **Threaded mode** (default): every adapter call degrades to the
//!   identical `Condvar::wait_timeout` / `thread::sleep` /
//!   `Instant`-arithmetic the call site used before, so the fidelity
//!   baseline is behaviour-preserving by construction.
//! * **Event mode**: ranks are cooperatively scheduled tasks. Exactly
//!   one task runs at a time (a run token passed through per-task
//!   permits); a park becomes a timer `(deadline_ns, seq, task)` in a
//!   binary heap, and when no task is ready the virtual clock jumps to
//!   the earliest deadline. No notify path exists — wakeups are purely
//!   timer-driven, so the lost-wakeup bug class is impossible and the
//!   schedule is a deterministic function of the task set alone.
//!
//! Tasks are still OS threads (small stacks, [`TASK_STACK_BYTES`]), so
//! rank code keeps its natural blocking style; the cooperative token
//! means one process comfortably hosts thousands of ranks. Threads that
//! are *not* registered tasks (the main thread, PJRT engine threads)
//! fall back to real waits — they interact with the virtual world only
//! through atomics and joins, never through its clock.

use std::cell::Cell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How ranks execute: one OS thread per rank parked on real condvars
/// (`Threaded`, the fidelity baseline) or cooperatively scheduled tasks
/// on a virtual clock (`Event`), selected by the `exec.mode` config key
/// or the `PARTREPER_EXEC` environment variable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Threaded,
    Event,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "threaded" => Some(ExecMode::Threaded),
            "event" => Some(ExecMode::Event),
            _ => None,
        }
    }

    /// Default mode, overridable by `PARTREPER_EXEC=event` (how ci.sh
    /// runs the whole tier-1 suite under the event scheduler).
    pub fn from_env() -> Self {
        match std::env::var("PARTREPER_EXEC") {
            Ok(v) => Self::parse(&v).unwrap_or(ExecMode::Threaded),
            Err(_) => ExecMode::Threaded,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Threaded => "threaded",
            ExecMode::Event => "event",
        }
    }
}

/// Cap on a single event-mode park. Callers are predicate loops, so a
/// long timeout sliced into capped parks is semantically identical —
/// and no task can oversleep an arrival by more than this much virtual
/// time, since event mode has no notify path to cut a park short.
const EVENT_PARK_CAP: Duration = Duration::from_millis(1);

/// Stack size for event-mode task threads. Virtual address space only;
/// 16k tasks cost 16 GiB of *reservation*, pennies on 64-bit.
pub const TASK_STACK_BYTES: usize = 1 << 20;

/// One run token slot: granted by the scheduler, consumed by the task.
struct Permit {
    granted: Mutex<bool>,
    cv: Condvar,
}

impl Permit {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            granted: Mutex::new(false),
            cv: Condvar::new(),
        })
    }

    fn grant(&self) {
        let mut g = self.granted.lock().unwrap();
        *g = true;
        self.cv.notify_one();
    }

    fn acquire(&self) {
        let mut g = self.granted.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
        *g = false;
    }
}

#[derive(Clone, Copy, PartialEq)]
enum TaskState {
    Ready,
    Running,
    Parked,
    Done,
}

/// A schedule-point observer (see [`Sched::set_point_hook`]): called with
/// the park's ordinal, on the yielding task's thread, outside the core
/// lock — free to poison ranks and wake fabrics.
pub type PointHook = Arc<dyn Fn(u64) + Send + Sync>;

/// Event-loop state. Exactly one task is `Running` (or the token is in
/// flight to the next grantee) at any instant; every `Parked` task owns
/// exactly one timer, so the heap never starves a sleeper.
struct Core {
    now_ns: u64,
    seq: u64,
    timers: BinaryHeap<Reverse<(u64, u64, usize)>>,
    ready: VecDeque<usize>,
    tasks: Vec<TaskState>,
    permits: Vec<Arc<Permit>>,
    started: bool,
    /// Scheduling decisions taken (grants).
    events: u64,
    /// Total virtual time the clock has jumped forward.
    advanced_ns: u64,
    /// High-water mark of the ready queue.
    ready_peak: u64,
    /// Schedule points taken (event-mode parks), hook installed or not.
    points: u64,
    /// The schedule-point hook, if armed.
    hook: Option<PointHook>,
}

/// Scheduler counters for the run summary: `(events_processed,
/// virtual_ns_advanced, max_ready_queue_depth)`.
pub type SchedSnapshot = (u64, u64, u64);

static NEXT_SCHED_ID: AtomicUsize = AtomicUsize::new(1);

thread_local! {
    /// `(sched id, task id)` of the task this thread runs, if any.
    static CURRENT: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

/// The clock + executor for one job world. Threaded mode is stateless
/// glue over the std primitives; event mode owns the task registry and
/// the virtual clock.
pub struct Sched {
    mode: ExecMode,
    id: usize,
    epoch: Instant,
    core: Mutex<Core>,
}

impl Sched {
    pub fn new(mode: ExecMode) -> Arc<Self> {
        Arc::new(Self {
            mode,
            id: NEXT_SCHED_ID.fetch_add(1, Ordering::Relaxed),
            epoch: Instant::now(),
            core: Mutex::new(Core {
                now_ns: 0,
                seq: 0,
                timers: BinaryHeap::new(),
                ready: VecDeque::new(),
                tasks: Vec::new(),
                permits: Vec::new(),
                started: false,
                events: 0,
                advanced_ns: 0,
                ready_peak: 0,
                points: 0,
                hook: None,
            }),
        })
    }

    /// A fresh threaded-mode clock — the drop-in for every call site
    /// that predates execution modes.
    pub fn threaded() -> Arc<Self> {
        Self::new(ExecMode::Threaded)
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn is_event(&self) -> bool {
        self.mode == ExecMode::Event
    }

    /// Monotone nanoseconds: wall-clock since this scheduler's creation
    /// (threaded) or the virtual clock (event).
    pub fn now_ns(&self) -> u64 {
        match self.mode {
            ExecMode::Threaded => self.epoch.elapsed().as_nanos() as u64,
            ExecMode::Event => self.core.lock().unwrap().now_ns,
        }
    }

    /// The task id of the calling thread, if it is one of ours.
    fn my_task(&self) -> Option<usize> {
        CURRENT.with(|c| c.get()).and_then(|(sid, task)| (sid == self.id).then_some(task))
    }

    /// Install the schedule-point hook: called once per event-mode park
    /// with that park's ordinal (0, 1, 2, … over the whole run). Event
    /// mode runs exactly one task at a time and every blocking point
    /// routes through a park, so the ordinal stream is a total order over
    /// the run's scheduling decisions — the failure-schedule explorer's
    /// injection coordinate system (DESIGN.md §10). Threaded mode never
    /// parks virtually, so the hook never fires there. Arm before
    /// [`Sched::start`]; the hook runs on the yielding task's thread with
    /// the core lock *released*, so it may poison ranks and wake fabrics.
    pub fn set_point_hook(&self, hook: impl Fn(u64) + Send + Sync + 'static) {
        self.core.lock().unwrap().hook = Some(Arc::new(hook));
    }

    /// Schedule points taken so far (event-mode parks; 0 in threaded
    /// mode). A failure-free probe run reads this to learn how many
    /// distinct injection coordinates the run exposes.
    pub fn points(&self) -> u64 {
        self.core.lock().unwrap().points
    }

    /// Scheduler counters (zeros in threaded mode).
    pub fn snapshot(&self) -> SchedSnapshot {
        if self.mode == ExecMode::Threaded {
            return (0, 0, 0);
        }
        let core = self.core.lock().unwrap();
        (core.events, core.advanced_ns, core.ready_peak)
    }

    // ---------------------------------------------------------- executor

    /// Spawn a rank/service body. Threaded: a plain named OS thread.
    /// Event: a task thread that blocks until the scheduler grants it
    /// the run token — nothing runs before [`Sched::start`].
    pub fn spawn<T: Send + 'static>(
        self: &Arc<Self>,
        name: &str,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> JoinHandle<T> {
        let builder = std::thread::Builder::new().name(name.to_string());
        match self.mode {
            ExecMode::Threaded => builder.spawn(f).expect("spawn thread"),
            ExecMode::Event => {
                let me = {
                    let mut core = self.core.lock().unwrap();
                    let me = core.tasks.len();
                    core.tasks.push(TaskState::Ready);
                    core.permits.push(Permit::new());
                    core.ready.push_back(me);
                    core.ready_peak = core.ready_peak.max(core.ready.len() as u64);
                    me
                };
                let sched = self.clone();
                builder
                    .stack_size(TASK_STACK_BYTES)
                    .spawn(move || {
                        let permit = {
                            let core = sched.core.lock().unwrap();
                            core.permits[me].clone()
                        };
                        permit.acquire();
                        CURRENT.with(|c| c.set(Some((sched.id, me))));
                        let out = catch_unwind(AssertUnwindSafe(f));
                        {
                            let mut core = sched.core.lock().unwrap();
                            core.tasks[me] = TaskState::Done;
                            sched.dispatch_locked(&mut core);
                        }
                        match out {
                            Ok(v) => v,
                            Err(p) => resume_unwind(p),
                        }
                    })
                    .expect("spawn task thread")
            }
        }
    }

    /// Release the first task (event mode; no-op threaded). Call once,
    /// after the initial task set is spawned.
    pub fn start(&self) {
        if self.mode != ExecMode::Event {
            return;
        }
        let mut core = self.core.lock().unwrap();
        if !core.started {
            core.started = true;
            self.dispatch_locked(&mut core);
        }
    }

    /// Hand the run token to the next runnable task: ready queue first
    /// (FIFO — spawn/wake order), else the earliest timer, advancing the
    /// virtual clock to its deadline. Caller holds the core lock and has
    /// already retired/parked the current holder, so granting here keeps
    /// the single-token invariant.
    fn dispatch_locked(&self, core: &mut Core) {
        core.events += 1;
        if let Some(t) = core.ready.pop_front() {
            core.tasks[t] = TaskState::Running;
            core.permits[t].grant();
            return;
        }
        while let Some(&Reverse((deadline, _, t))) = core.timers.peek() {
            core.timers.pop();
            if core.tasks[t] != TaskState::Parked {
                continue;
            }
            if deadline > core.now_ns {
                core.advanced_ns += deadline - core.now_ns;
                core.now_ns = deadline;
            }
            core.tasks[t] = TaskState::Running;
            core.permits[t].grant();
            return;
        }
        // Nothing runnable: every task is Done (or none were spawned).
        // Parked implies a timer, so this cannot strand a sleeper.
    }

    /// Park task `me` until virtual `deadline`, yielding the token.
    fn park_until_locked(&self, me: usize, deadline: u64) {
        // Schedule point: number this park and run the hook *before*
        // yielding, outside the lock. Only the current token holder can
        // be here, so ordinals are a deterministic total order, and a
        // hook-injected poison lands before any other task observes the
        // world again — the injection is pinned to this exact decision.
        let hook = {
            let mut core = self.core.lock().unwrap();
            let idx = core.points;
            core.points += 1;
            core.hook.as_ref().map(|h| (h.clone(), idx))
        };
        if let Some((h, idx)) = hook {
            h(idx);
        }
        let permit = {
            let mut core = self.core.lock().unwrap();
            // Always move time forward: a zero-length park still yields
            // (and re-acquires) deterministically instead of spinning.
            let deadline = deadline.max(core.now_ns + 1);
            core.seq += 1;
            let seq = core.seq;
            core.timers.push(Reverse((deadline, seq, me)));
            core.tasks[me] = TaskState::Parked;
            let permit = core.permits[me].clone();
            self.dispatch_locked(&mut core);
            permit
        };
        permit.acquire();
    }

    // ------------------------------------------------------------- clock

    /// Sleep for `dur`: real sleep (threaded / foreign threads), virtual
    /// park (event-mode tasks).
    pub fn sleep(&self, dur: Duration) {
        match (self.mode, self.my_task()) {
            (ExecMode::Event, Some(me)) => {
                let now = self.core.lock().unwrap().now_ns;
                self.park_until_locked(me, now.saturating_add(dur.as_nanos() as u64));
            }
            _ => std::thread::sleep(dur),
        }
    }

    /// Wait until the clock reaches `target_ns`. Threaded keeps the
    /// fabric's historical busy-spin (NIC settle fidelity); event-mode
    /// tasks park, turning wire time into pure virtual time.
    pub fn wait_until_ns(&self, target_ns: u64) {
        match (self.mode, self.my_task()) {
            (ExecMode::Event, Some(me)) => {
                if self.core.lock().unwrap().now_ns < target_ns {
                    self.park_until_locked(me, target_ns);
                }
            }
            (ExecMode::Event, None) => {
                // A foreign thread settling against the virtual clock:
                // yield real time until the task world catches up.
                while self.now_ns() < target_ns {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
            _ => {
                while self.now_ns() < target_ns {
                    std::hint::spin_loop();
                }
            }
        }
    }

    /// The universal blocking-point adapter: every `cv.wait_timeout`
    /// park in a predicate loop routes through here. Threaded mode is
    /// the exact historical wait; event mode drops the guard, parks on a
    /// (capped) virtual timer — senders never notify across the mode
    /// boundary — and re-locks. Callers re-check their predicate on
    /// return, which is what makes the capped slice legal.
    pub fn wait_timeout<'a, T>(
        &self,
        lock: &'a Mutex<T>,
        guard: MutexGuard<'a, T>,
        cv: &Condvar,
        dur: Duration,
    ) -> MutexGuard<'a, T> {
        match (self.mode, self.my_task()) {
            (ExecMode::Event, Some(me)) => {
                drop(guard);
                let slice = dur.min(EVENT_PARK_CAP);
                let now = self.core.lock().unwrap().now_ns;
                self.park_until_locked(me, now.saturating_add(slice.as_nanos() as u64));
                lock.lock().unwrap()
            }
            (ExecMode::Event, None) => cv.wait_timeout(guard, dur.min(EVENT_PARK_CAP)).unwrap().0,
            _ => cv.wait_timeout(guard, dur).unwrap().0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threaded_clock_is_monotone_wall_time() {
        let s = Sched::threaded();
        let a = s.now_ns();
        std::thread::sleep(Duration::from_millis(2));
        let b = s.now_ns();
        assert!(b > a, "clock must advance: {a} -> {b}");
        assert_eq!(s.snapshot(), (0, 0, 0));
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!(ExecMode::parse("threaded"), Some(ExecMode::Threaded));
        assert_eq!(ExecMode::parse("event"), Some(ExecMode::Event));
        assert_eq!(ExecMode::parse("bogus"), None);
        assert_eq!(ExecMode::Event.name(), "event");
    }

    #[test]
    fn event_tasks_interleave_on_virtual_time() {
        let s = Sched::new(ExecMode::Event);
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for id in 0..3usize {
            let s2 = s.clone();
            let log2 = log.clone();
            handles.push(s.spawn(&format!("task-{id}"), move || {
                for step in 0..4 {
                    log2.lock().unwrap().push((id, step));
                    s2.sleep(Duration::from_micros(100));
                }
            }));
        }
        s.start();
        for h in handles {
            h.join().unwrap();
        }
        let log = log.lock().unwrap();
        assert_eq!(log.len(), 12);
        // Round-robin: equal sleeps + FIFO seq order keep spawn order.
        let first_round: Vec<usize> = log[0..3].iter().map(|&(id, _)| id).collect();
        assert_eq!(first_round, vec![0, 1, 2]);
        let (events, advanced, _) = s.snapshot();
        assert!(events >= 12, "events {events}");
        assert!(advanced >= 300, "virtual time advanced {advanced}");
        // Virtual time moved ~400us regardless of wall speed.
        assert!(s.now_ns() >= 4 * 100_000 - EVENT_PARK_CAP.as_nanos() as u64);
    }

    #[test]
    fn event_schedule_is_deterministic() {
        let run = || {
            let s = Sched::new(ExecMode::Event);
            let log = Arc::new(Mutex::new(Vec::new()));
            let mut handles = Vec::new();
            for id in 0..4usize {
                let s2 = s.clone();
                let log2 = log.clone();
                handles.push(s.spawn(&format!("t{id}"), move || {
                    for step in 0..5 {
                        log2.lock().unwrap().push((id, step, s2.now_ns()));
                        // Unequal ticks exercise heap ordering.
                        s2.sleep(Duration::from_micros(50 + 30 * id as u64));
                    }
                }));
            }
            s.start();
            for h in handles {
                h.join().unwrap();
            }
            let order = log.lock().unwrap().clone();
            (order, s.snapshot())
        };
        assert_eq!(run(), run(), "same task set must replay identically");
    }

    #[test]
    fn adapter_wait_times_out_in_both_modes() {
        for mode in [ExecMode::Threaded, ExecMode::Event] {
            let s = Sched::new(mode);
            let state: Arc<(Mutex<bool>, Condvar)> = Arc::new((Mutex::new(false), Condvar::new()));
            let s2 = s.clone();
            let st2 = state.clone();
            let h = s.spawn("waiter", move || {
                let (m, cv) = &*st2;
                let mut g = m.lock().unwrap();
                let mut spins = 0u32;
                while !*g {
                    g = s2.wait_timeout(m, g, cv, Duration::from_micros(200));
                    spins += 1;
                    if spins > 10 {
                        // Nobody will ever flip the flag: the capped,
                        // notify-free park loop still makes progress.
                        return spins;
                    }
                }
                spins
            });
            s.start();
            let spins = h.join().unwrap();
            assert!(spins > 10, "mode {mode:?} wedged at {spins}");
        }
    }

    #[test]
    fn point_hook_sees_a_dense_deterministic_ordinal_stream() {
        let run = || {
            let s = Sched::new(ExecMode::Event);
            let seen = Arc::new(Mutex::new(Vec::new()));
            let seen2 = seen.clone();
            s.set_point_hook(move |idx| seen2.lock().unwrap().push(idx));
            let mut handles = Vec::new();
            for id in 0..3usize {
                let s2 = s.clone();
                handles.push(s.spawn(&format!("t{id}"), move || {
                    for _ in 0..4 {
                        s2.sleep(Duration::from_micros(70 + 11 * id as u64));
                    }
                }));
            }
            s.start();
            for h in handles {
                h.join().unwrap();
            }
            let seen = seen.lock().unwrap().clone();
            (seen, s.points())
        };
        let (seen, total) = run();
        // Ordinals are dense: 0, 1, 2, … with no gaps or reordering.
        let want: Vec<u64> = (0..seen.len() as u64).collect();
        assert_eq!(seen, want);
        assert_eq!(total, seen.len() as u64);
        assert!(total >= 12, "each of 12 sleeps parks at least once");
        assert_eq!(run(), (seen, total), "point stream must replay identically");
    }

    #[test]
    fn threaded_mode_exposes_no_schedule_points() {
        let s = Sched::threaded();
        s.set_point_hook(|_| panic!("threaded mode must never park virtually"));
        let h = s.spawn("t", {
            let s2 = s.clone();
            move || s2.sleep(Duration::from_micros(50))
        });
        s.start();
        h.join().unwrap();
        assert_eq!(s.points(), 0);
    }

    #[test]
    fn tasks_spawned_mid_run_get_scheduled() {
        let s = Sched::new(ExecMode::Event);
        let hit = Arc::new(Mutex::new(false));
        let s2 = s.clone();
        let hit2 = hit.clone();
        let h = s.spawn("parent", move || {
            let hit3 = hit2.clone();
            let child = s2.spawn("child", move || {
                *hit3.lock().unwrap() = true;
            });
            // Parent parks; token flows to the child.
            let s3 = s2.clone();
            while !*hit2.lock().unwrap() {
                s3.sleep(Duration::from_micros(100));
            }
            child.join().unwrap();
        });
        s.start();
        h.join().unwrap();
        assert!(*hit.lock().unwrap());
    }
}
