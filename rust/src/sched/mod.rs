//! Execution-mode scheduler: the `Clock`/`Executor` seam between the
//! threaded fabric and the discrete-event virtual-clock world (DESIGN.md
//! §8).
//!
//! Every blocking point in the runtime — `Fabric::wait_new_mail`, the
//! rendezvous gate behind `SendHandle`, the request engine's park loop,
//! OMPI consensus parking, the monitor's detect tick, the fault
//! injector's Weibull sleeps — is already a *bounded poll*: park for a
//! tick, re-check a predicate, repeat. [`Sched`] virtualizes exactly
//! that tick and nothing else:
//!
//! * **Threaded mode** (default): every adapter call degrades to the
//!   identical `Condvar::wait_timeout` / `thread::sleep` /
//!   `Instant`-arithmetic the call site used before, so the fidelity
//!   baseline is behaviour-preserving by construction.
//! * **Event mode**: ranks are cooperatively scheduled tasks. Exactly
//!   one task runs at a time (a run token passed through per-task
//!   permits); a park becomes a timer entry in a binary heap, and when
//!   no task is ready the virtual clock jumps to the earliest deadline.
//!   Wakeups are timer-driven, but producers may *retime* a parked
//!   consumer's entry to the delivery instant through a [`WakeHandle`]
//!   (a **wake edge**): the heap is lazy-deletion (stale entries carry
//!   an old per-task generation and are skipped on pop), so a retime is
//!   one O(log n) push, and because only the single running task can
//!   fire it, the retime is itself a deterministic event on the virtual
//!   clock. A missed edge is never fatal — every wakable park keeps a
//!   fallback timer and its caller re-checks a predicate, so the worst
//!   case degrades to polling, it never wedges.
//!
//! Tasks are still OS threads (small stacks, [`TASK_STACK_BYTES`] by
//! default, `sched.stack_bytes` to override), so rank code keeps its
//! natural blocking style; the cooperative token means one process
//! comfortably hosts tens of thousands of ranks. Threads that are *not*
//! registered tasks (the main thread, PJRT engine threads) fall back to
//! real waits — they interact with the virtual world only through
//! atomics and joins, never through its clock.

use std::cell::Cell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How ranks execute: one OS thread per rank parked on real condvars
/// (`Threaded`, the fidelity baseline) or cooperatively scheduled tasks
/// on a virtual clock (`Event`), selected by the `exec.mode` config key
/// or the `PARTREPER_EXEC` environment variable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Threaded,
    Event,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "threaded" => Some(ExecMode::Threaded),
            "event" => Some(ExecMode::Event),
            _ => None,
        }
    }

    /// Default mode, overridable by `PARTREPER_EXEC=event` (how ci.sh
    /// runs the whole tier-1 suite under the event scheduler).
    pub fn from_env() -> Self {
        match std::env::var("PARTREPER_EXEC") {
            Ok(v) => Self::parse(&v).unwrap_or(ExecMode::Threaded),
            Err(_) => ExecMode::Threaded,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Threaded => "threaded",
            ExecMode::Event => "event",
        }
    }
}

/// Cap on a *foreign* (non-task) thread's real condvar wait against the
/// virtual world: such a thread polls virtual state at real intervals,
/// so the cap bounds how stale its view can get. Task parks are NOT
/// capped — they run their full requested duration and rely on wake
/// edges (or their predicate loop's fallback tick) for liveness.
const EVENT_PARK_CAP: Duration = Duration::from_millis(1);

/// Fallback floor applied by [`Sched::fallback_tick`] to event-mode
/// predicate-loop parks that have a registered wake edge: the edge does
/// the waking, so the poll tick only bounds recovery from a missed edge
/// and can be two orders of magnitude lazier than the threaded-mode
/// tick without costing latency.
const EVENT_FALLBACK_TICK: Duration = Duration::from_millis(10);

/// Default stack size for event-mode task threads. Virtual address
/// space only; 16k tasks cost 16 GiB of *reservation*, pennies on
/// 64-bit. Override per job via `sched.stack_bytes` (the 64k+-rank
/// fig9b worlds shrink it to fit OS map-count ceilings — see README).
pub const TASK_STACK_BYTES: usize = 1 << 20;

/// Smallest stack [`Sched::with_stack_bytes`] will accept: enough for
/// the deepest runtime path (collective recursion + error handler) with
/// guard-page headroom.
pub const MIN_STACK_BYTES: usize = 64 << 10;

/// One run token slot: granted by the scheduler, consumed by the task.
/// Lock-free hot path — a grant is one release store + `unpark`, an
/// acquire is one CAS (the unpark token makes the register/park race
/// benign: an unpark delivered before the park buffers and the park
/// returns immediately). The scheduler's single-token invariant means
/// at most one grant is ever outstanding per permit.
struct Permit {
    granted: AtomicBool,
    /// The owning task's thread, registered on first acquire. Tasks are
    /// pinned to their thread for life, so one registration suffices.
    waiter: OnceLock<std::thread::Thread>,
}

impl Permit {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            granted: AtomicBool::new(false),
            waiter: OnceLock::new(),
        })
    }

    fn grant(&self) {
        self.granted.store(true, Ordering::Release);
        if let Some(t) = self.waiter.get() {
            t.unpark();
        }
    }

    fn acquire(&self) {
        let _ = self.waiter.set(std::thread::current());
        while self
            .granted
            .compare_exchange(true, false, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            std::thread::park();
        }
    }
}

#[derive(Clone, Copy, PartialEq)]
enum TaskState {
    Ready,
    Running,
    Parked,
    Done,
}

/// One timer-heap entry. The heap is min-ordered by `(deadline, seq)`
/// (derive order — later fields never tie because `seq` is unique);
/// `gen` implements lazy deletion: a pop whose `gen` doesn't match the
/// task's current generation is a leftover from an earlier park (or an
/// already-serviced retime) and is skipped. `edge` marks retime entries
/// so the empty-park accounting can tell a productive wake from a
/// fallback timeout.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct TimerEntry {
    deadline: u64,
    seq: u64,
    task: usize,
    gen: u64,
    edge: bool,
}

/// A schedule-point observer (see [`Sched::set_point_hook`]): called with
/// the park's ordinal, on the yielding task's thread, outside the core
/// lock — free to poison ranks and wake fabrics.
pub type PointHook = Arc<dyn Fn(u64) + Send + Sync>;

/// Event-loop state. Exactly one task is `Running` (or the token is in
/// flight to the next grantee) at any instant; every `Parked` task owns
/// at least one live timer, so the heap never starves a sleeper.
struct Core {
    now_ns: u64,
    seq: u64,
    timers: BinaryHeap<Reverse<TimerEntry>>,
    ready: VecDeque<usize>,
    tasks: Vec<TaskState>,
    permits: Vec<Arc<Permit>>,
    /// Per-task timer generation; bumped on every grant so all entries
    /// pushed for earlier parks (or duplicate retimes of this one) go
    /// stale at once.
    gens: Vec<u64>,
    /// Whether the task's current park may legally be cut short by a
    /// retime (predicate-loop fallback ticks: yes; `sleep` /
    /// `wait_until_ns` exact waits: no — they ARE the time model).
    wakable: Vec<bool>,
    started: bool,
    /// Scheduling decisions taken (grants).
    events: u64,
    /// Total virtual time the clock has jumped forward.
    advanced_ns: u64,
    /// High-water mark of the ready queue.
    ready_peak: u64,
    /// Retime pushes taken through [`WakeHandle`]s.
    wake_edges: u64,
    /// Wakable parks that expired on their fallback timer instead of a
    /// wake edge — the polling waste the edges exist to remove.
    empty_parks: u64,
    /// Schedule points taken (event-mode parks), hook installed or not.
    points: u64,
    /// The schedule-point hook, if armed.
    hook: Option<PointHook>,
}

/// Scheduler counters for the run summary. All zeros in threaded mode.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedSnapshot {
    /// Scheduling decisions taken (grants).
    pub events: u64,
    /// Total virtual nanoseconds the clock advanced.
    pub advanced_ns: u64,
    /// High-water mark of the ready queue.
    pub ready_peak: u64,
    /// Wake edges fired (retimes of parked waiters to delivery instants).
    pub wake_edges: u64,
    /// Wakable parks that ran to their fallback timeout with nothing to
    /// do — the empty-poll waste; `empty_parks / events` is fig9b's
    /// empty-park ratio.
    pub empty_parks: u64,
}

static NEXT_SCHED_ID: AtomicUsize = AtomicUsize::new(1);

thread_local! {
    /// `(sched id, task id)` of the task this thread runs, if any.
    static CURRENT: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

/// A producer-side handle to one parked consumer task: calling
/// [`WakeHandle::wake_at`] retimes the consumer's fallback timer to the
/// delivery instant (a wake edge). Cheap to clone and to fire on a task
/// that is no longer parked (the retime is dropped). Handles are minted
/// by the consumer itself via [`Sched::wake_handle`] and registered
/// with its wake source (a mailbox, a rendezvous gate) before parking.
#[derive(Clone)]
pub struct WakeHandle {
    sched: Arc<Sched>,
    task: usize,
}

impl WakeHandle {
    /// The task this handle wakes (used by wake sources to deduplicate
    /// registrations).
    pub fn task(&self) -> usize {
        self.task
    }

    /// Wake the task now (virtual now — the retime clamps to the
    /// current clock).
    pub fn wake(&self) {
        self.sched.retime(self.task, 0);
    }

    /// Retime the task's park to virtual instant `ns` (clamped to the
    /// current clock so time never rewinds).
    pub fn wake_at(&self, ns: u64) {
        self.sched.retime(self.task, ns);
    }
}

/// The clock + executor for one job world. Threaded mode is stateless
/// glue over the std primitives; event mode owns the task registry and
/// the virtual clock.
pub struct Sched {
    mode: ExecMode,
    id: usize,
    epoch: Instant,
    /// Stack reservation per event-mode task thread (`sched.stack_bytes`).
    stack_bytes: usize,
    core: Mutex<Core>,
}

impl Sched {
    pub fn new(mode: ExecMode) -> Arc<Self> {
        Self::with_stack_bytes(mode, TASK_STACK_BYTES)
    }

    /// Build a scheduler with an explicit per-task stack reservation
    /// (event mode only; threaded spawns use the platform default).
    /// Floored at [`MIN_STACK_BYTES`]. The ≥64k-rank fig9b worlds pass
    /// small stacks here to stay under the OS thread/map ceilings
    /// documented in the README.
    pub fn with_stack_bytes(mode: ExecMode, stack_bytes: usize) -> Arc<Self> {
        Arc::new(Self {
            mode,
            id: NEXT_SCHED_ID.fetch_add(1, Ordering::Relaxed),
            epoch: Instant::now(),
            stack_bytes: stack_bytes.max(MIN_STACK_BYTES),
            core: Mutex::new(Core {
                now_ns: 0,
                seq: 0,
                timers: BinaryHeap::new(),
                ready: VecDeque::new(),
                tasks: Vec::new(),
                permits: Vec::new(),
                gens: Vec::new(),
                wakable: Vec::new(),
                started: false,
                events: 0,
                advanced_ns: 0,
                ready_peak: 0,
                wake_edges: 0,
                empty_parks: 0,
                points: 0,
                hook: None,
            }),
        })
    }

    /// A fresh threaded-mode clock — the drop-in for every call site
    /// that predates execution modes.
    pub fn threaded() -> Arc<Self> {
        Self::new(ExecMode::Threaded)
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn is_event(&self) -> bool {
        self.mode == ExecMode::Event
    }

    /// Monotone nanoseconds: wall-clock since this scheduler's creation
    /// (threaded) or the virtual clock (event).
    pub fn now_ns(&self) -> u64 {
        match self.mode {
            ExecMode::Threaded => self.epoch.elapsed().as_nanos() as u64,
            ExecMode::Event => self.core.lock().unwrap().now_ns,
        }
    }

    /// The task id of the calling thread, if it is one of ours.
    fn my_task(&self) -> Option<usize> {
        CURRENT.with(|c| c.get()).and_then(|(sid, task)| (sid == self.id).then_some(task))
    }

    /// A [`WakeHandle`] for the calling task, or `None` when the caller
    /// is not an event-mode task (threaded mode, foreign threads) —
    /// wake sources treat `None` as "nothing to register", which keeps
    /// threaded behaviour untouched.
    pub fn wake_handle(self: &Arc<Self>) -> Option<WakeHandle> {
        if self.mode != ExecMode::Event {
            return None;
        }
        self.my_task().map(|task| WakeHandle {
            sched: self.clone(),
            task,
        })
    }

    /// Lengthen a predicate-loop fallback tick in event mode (identity
    /// in threaded mode): parks that registered a wake edge are woken at
    /// delivery time, so their poll tick only bounds missed-edge
    /// recovery and failure/poison observation latency — both of which
    /// also fire `Fabric::wake_all`-style edges on the hot paths.
    pub fn fallback_tick(&self, tick: Duration) -> Duration {
        if self.is_event() {
            tick.max(EVENT_FALLBACK_TICK)
        } else {
            tick
        }
    }

    /// Retime `task`'s current park to virtual instant `target_ns`
    /// (clamped to now): one lazy-deletion heap push, O(log n). A no-op
    /// unless the task is parked *wakably* — exact waits (`sleep`,
    /// `wait_until_ns`, NIC settles) are the time model itself and must
    /// never be cut short. Only the running task (or a foreign thread
    /// that the running task is synchronizing with) can call this, so
    /// the retime is totally ordered on the virtual clock — the §8
    /// determinism argument.
    pub fn retime(&self, task: usize, target_ns: u64) {
        if self.mode != ExecMode::Event {
            return;
        }
        let mut core = self.core.lock().unwrap();
        if task >= core.tasks.len() || core.tasks[task] != TaskState::Parked || !core.wakable[task]
        {
            return;
        }
        let deadline = target_ns.max(core.now_ns);
        core.seq += 1;
        let entry = TimerEntry {
            deadline,
            seq: core.seq,
            task,
            gen: core.gens[task],
            edge: true,
        };
        core.timers.push(Reverse(entry));
        core.wake_edges += 1;
    }

    /// Install the schedule-point hook: called once per event-mode park
    /// with that park's ordinal (0, 1, 2, … over the whole run). Event
    /// mode runs exactly one task at a time and every blocking point
    /// routes through a park, so the ordinal stream is a total order over
    /// the run's scheduling decisions — the failure-schedule explorer's
    /// injection coordinate system (DESIGN.md §10). Wake-edge retimes
    /// are not parks and take no ordinal. Threaded mode never parks
    /// virtually, so the hook never fires there. Arm before
    /// [`Sched::start`]; the hook runs on the yielding task's thread with
    /// the core lock *released*, so it may poison ranks and wake fabrics.
    pub fn set_point_hook(&self, hook: impl Fn(u64) + Send + Sync + 'static) {
        self.core.lock().unwrap().hook = Some(Arc::new(hook));
    }

    /// Schedule points taken so far (event-mode parks; 0 in threaded
    /// mode). A failure-free probe run reads this to learn how many
    /// distinct injection coordinates the run exposes.
    pub fn points(&self) -> u64 {
        self.core.lock().unwrap().points
    }

    /// Scheduler counters (zeros in threaded mode).
    pub fn snapshot(&self) -> SchedSnapshot {
        if self.mode == ExecMode::Threaded {
            return SchedSnapshot::default();
        }
        let core = self.core.lock().unwrap();
        SchedSnapshot {
            events: core.events,
            advanced_ns: core.advanced_ns,
            ready_peak: core.ready_peak,
            wake_edges: core.wake_edges,
            empty_parks: core.empty_parks,
        }
    }

    // ---------------------------------------------------------- executor

    /// Spawn a rank/service body. Threaded: a plain named OS thread.
    /// Event: a task thread (stack per [`Sched::with_stack_bytes`]) that
    /// blocks until the scheduler grants it the run token — nothing runs
    /// before [`Sched::start`].
    pub fn spawn<T: Send + 'static>(
        self: &Arc<Self>,
        name: &str,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> JoinHandle<T> {
        let builder = std::thread::Builder::new().name(name.to_string());
        match self.mode {
            ExecMode::Threaded => builder.spawn(f).expect("spawn thread"),
            ExecMode::Event => {
                let me = {
                    let mut core = self.core.lock().unwrap();
                    let me = core.tasks.len();
                    core.tasks.push(TaskState::Ready);
                    core.permits.push(Permit::new());
                    core.gens.push(0);
                    core.wakable.push(false);
                    core.ready.push_back(me);
                    core.ready_peak = core.ready_peak.max(core.ready.len() as u64);
                    me
                };
                let sched = self.clone();
                builder
                    .stack_size(self.stack_bytes)
                    .spawn(move || {
                        let permit = {
                            let core = sched.core.lock().unwrap();
                            core.permits[me].clone()
                        };
                        permit.acquire();
                        CURRENT.with(|c| c.set(Some((sched.id, me))));
                        let out = catch_unwind(AssertUnwindSafe(f));
                        {
                            let mut core = sched.core.lock().unwrap();
                            core.tasks[me] = TaskState::Done;
                            sched.dispatch_locked(&mut core);
                        }
                        match out {
                            Ok(v) => v,
                            Err(p) => resume_unwind(p),
                        }
                    })
                    .expect("spawn task thread")
            }
        }
    }

    /// Release the first task (event mode; no-op threaded). Call once,
    /// after the initial task set is spawned.
    pub fn start(&self) {
        if self.mode != ExecMode::Event {
            return;
        }
        let mut core = self.core.lock().unwrap();
        if !core.started {
            core.started = true;
            self.dispatch_locked(&mut core);
        }
    }

    /// Hand the run token to the next runnable task: ready queue first
    /// (FIFO — spawn/wake order), else the earliest live timer, advancing
    /// the virtual clock to its deadline. Stale heap entries (old
    /// generation, or their task not parked) are popped and dropped —
    /// lazy deletion. Granting bumps the task's generation so every
    /// remaining entry for the ending park goes stale at once. Caller
    /// holds the core lock and has already retired/parked the current
    /// holder, so granting here keeps the single-token invariant.
    fn dispatch_locked(&self, core: &mut Core) {
        core.events += 1;
        if let Some(t) = core.ready.pop_front() {
            core.tasks[t] = TaskState::Running;
            core.gens[t] = core.gens[t].wrapping_add(1);
            core.permits[t].grant();
            return;
        }
        while let Some(&Reverse(e)) = core.timers.peek() {
            core.timers.pop();
            let t = e.task;
            if core.tasks[t] != TaskState::Parked || e.gen != core.gens[t] {
                continue;
            }
            if e.deadline > core.now_ns {
                core.advanced_ns += e.deadline - core.now_ns;
                core.now_ns = e.deadline;
            }
            if !e.edge && core.wakable[t] {
                // A fallback poll tick ran to completion with no edge:
                // either nothing happened (idle poll) or an edge was
                // missed — both are the waste this counter surfaces.
                core.empty_parks += 1;
            }
            core.tasks[t] = TaskState::Running;
            core.gens[t] = core.gens[t].wrapping_add(1);
            core.permits[t].grant();
            return;
        }
        // Nothing runnable: every task is Done (or none were spawned).
        // Parked implies a live timer, so this cannot strand a sleeper.
    }

    /// Park task `me` until virtual `deadline`, yielding the token.
    /// `wakable` marks whether a [`WakeHandle::wake_at`] may legally cut
    /// the park short (predicate-loop fallback ticks) or the deadline is
    /// exact (`sleep`, `wait_until_ns` — the time model itself).
    fn park_until_locked(&self, me: usize, deadline: u64, wakable: bool) {
        // Schedule point: number this park and run the hook *before*
        // yielding, outside the lock. Only the current token holder can
        // be here, so ordinals are a deterministic total order, and a
        // hook-injected poison lands before any other task observes the
        // world again — the injection is pinned to this exact decision.
        let hook = {
            let mut core = self.core.lock().unwrap();
            let idx = core.points;
            core.points += 1;
            core.hook.as_ref().map(|h| (h.clone(), idx))
        };
        if let Some((h, idx)) = hook {
            h(idx);
        }
        let permit = {
            let mut core = self.core.lock().unwrap();
            // Always move time forward: a zero-length park still yields
            // (and re-acquires) deterministically instead of spinning.
            let deadline = deadline.max(core.now_ns + 1);
            core.seq += 1;
            let entry = TimerEntry {
                deadline,
                seq: core.seq,
                task: me,
                gen: core.gens[me],
                edge: false,
            };
            core.timers.push(Reverse(entry));
            core.tasks[me] = TaskState::Parked;
            core.wakable[me] = wakable;
            let permit = core.permits[me].clone();
            self.dispatch_locked(&mut core);
            permit
        };
        permit.acquire();
    }

    // ------------------------------------------------------------- clock

    /// Sleep for `dur`: real sleep (threaded / foreign threads), virtual
    /// park (event-mode tasks). Exact — never cut short by a wake edge
    /// (the injector's Weibull gaps and tick cadences depend on it).
    pub fn sleep(&self, dur: Duration) {
        match (self.mode, self.my_task()) {
            (ExecMode::Event, Some(me)) => {
                let now = self.core.lock().unwrap().now_ns;
                self.park_until_locked(me, now.saturating_add(dur.as_nanos() as u64), false);
            }
            _ => std::thread::sleep(dur),
        }
    }

    /// Wait until the clock reaches `target_ns`. Threaded keeps the
    /// fabric's historical busy-spin (NIC settle fidelity); event-mode
    /// tasks park exactly (the NIC settle IS the time model — a wake
    /// edge must never cut it short), turning wire time into pure
    /// virtual time.
    pub fn wait_until_ns(&self, target_ns: u64) {
        match (self.mode, self.my_task()) {
            (ExecMode::Event, Some(me)) => {
                if self.core.lock().unwrap().now_ns < target_ns {
                    self.park_until_locked(me, target_ns, false);
                }
            }
            (ExecMode::Event, None) => {
                // A foreign thread settling against the virtual clock:
                // yield real time until the task world catches up.
                while self.now_ns() < target_ns {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
            _ => {
                while self.now_ns() < target_ns {
                    std::hint::spin_loop();
                }
            }
        }
    }

    /// The universal blocking-point adapter: every `cv.wait_timeout`
    /// park in a predicate loop routes through here. Threaded mode is
    /// the exact historical wait; an event-mode task drops the guard,
    /// parks on a *wakable* virtual timer for the full duration — a
    /// registered wake edge retimes it to the delivery instant, and the
    /// caller's predicate re-check on return is what makes both the
    /// edge-wake and the fallback-timeout paths legal. Foreign threads
    /// keep a capped real wait so their view of the virtual world is
    /// bounded-stale.
    pub fn wait_timeout<'a, T>(
        &self,
        lock: &'a Mutex<T>,
        guard: MutexGuard<'a, T>,
        cv: &Condvar,
        dur: Duration,
    ) -> MutexGuard<'a, T> {
        match (self.mode, self.my_task()) {
            (ExecMode::Event, Some(me)) => {
                drop(guard);
                let now = self.core.lock().unwrap().now_ns;
                self.park_until_locked(me, now.saturating_add(dur.as_nanos() as u64), true);
                lock.lock().unwrap()
            }
            (ExecMode::Event, None) => cv.wait_timeout(guard, dur.min(EVENT_PARK_CAP)).unwrap().0,
            _ => cv.wait_timeout(guard, dur).unwrap().0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threaded_clock_is_monotone_wall_time() {
        let s = Sched::threaded();
        let a = s.now_ns();
        std::thread::sleep(Duration::from_millis(2));
        let b = s.now_ns();
        assert!(b > a, "clock must advance: {a} -> {b}");
        assert_eq!(s.snapshot(), SchedSnapshot::default());
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!(ExecMode::parse("threaded"), Some(ExecMode::Threaded));
        assert_eq!(ExecMode::parse("event"), Some(ExecMode::Event));
        assert_eq!(ExecMode::parse("bogus"), None);
        assert_eq!(ExecMode::Event.name(), "event");
    }

    #[test]
    fn event_tasks_interleave_on_virtual_time() {
        let s = Sched::new(ExecMode::Event);
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for id in 0..3usize {
            let s2 = s.clone();
            let log2 = log.clone();
            handles.push(s.spawn(&format!("task-{id}"), move || {
                for step in 0..4 {
                    log2.lock().unwrap().push((id, step));
                    s2.sleep(Duration::from_micros(100));
                }
            }));
        }
        s.start();
        for h in handles {
            h.join().unwrap();
        }
        let log = log.lock().unwrap();
        assert_eq!(log.len(), 12);
        // Round-robin: equal sleeps + FIFO seq order keep spawn order.
        let first_round: Vec<usize> = log[0..3].iter().map(|&(id, _)| id).collect();
        assert_eq!(first_round, vec![0, 1, 2]);
        let snap = s.snapshot();
        assert!(snap.events >= 12, "events {}", snap.events);
        assert!(snap.advanced_ns >= 300, "virtual time advanced {}", snap.advanced_ns);
        // Sleeps are exact timers: virtual time covers all 4 rounds.
        assert!(s.now_ns() >= 4 * 100_000);
    }

    #[test]
    fn event_schedule_is_deterministic() {
        let run = || {
            let s = Sched::new(ExecMode::Event);
            let log = Arc::new(Mutex::new(Vec::new()));
            let mut handles = Vec::new();
            for id in 0..4usize {
                let s2 = s.clone();
                let log2 = log.clone();
                handles.push(s.spawn(&format!("t{id}"), move || {
                    for step in 0..5 {
                        log2.lock().unwrap().push((id, step, s2.now_ns()));
                        // Unequal ticks exercise heap ordering.
                        s2.sleep(Duration::from_micros(50 + 30 * id as u64));
                    }
                }));
            }
            s.start();
            for h in handles {
                h.join().unwrap();
            }
            let order = log.lock().unwrap().clone();
            (order, s.snapshot())
        };
        assert_eq!(run(), run(), "same task set must replay identically");
    }

    #[test]
    fn adapter_wait_times_out_in_both_modes() {
        for mode in [ExecMode::Threaded, ExecMode::Event] {
            let s = Sched::new(mode);
            let state: Arc<(Mutex<bool>, Condvar)> = Arc::new((Mutex::new(false), Condvar::new()));
            let s2 = s.clone();
            let st2 = state.clone();
            let h = s.spawn("waiter", move || {
                let (m, cv) = &*st2;
                let mut g = m.lock().unwrap();
                let mut spins = 0u32;
                while !*g {
                    g = s2.wait_timeout(m, g, cv, Duration::from_micros(200));
                    spins += 1;
                    if spins > 10 {
                        // Nobody will ever flip the flag (and no wake
                        // edge is registered): the fallback-timer park
                        // loop still makes progress on its own.
                        return spins;
                    }
                }
                spins
            });
            s.start();
            let spins = h.join().unwrap();
            assert!(spins > 10, "mode {mode:?} wedged at {spins}");
        }
    }

    #[test]
    fn wake_edges_cut_parks_short_but_never_early() {
        let s = Sched::new(ExecMode::Event);
        let slot: Arc<Mutex<Option<WakeHandle>>> = Arc::new(Mutex::new(None));
        let state: Arc<(Mutex<bool>, Condvar)> = Arc::new((Mutex::new(false), Condvar::new()));
        let woke_at = Arc::new(Mutex::new(0u64));
        let (s2, slot2, st2, woke2) = (s.clone(), slot.clone(), state.clone(), woke_at.clone());
        let hw = s.spawn("waiter", move || {
            *slot2.lock().unwrap() = Some(s2.wake_handle().unwrap());
            let (m, cv) = &*st2;
            let mut g = m.lock().unwrap();
            while !*g {
                // Long fallback: without the edge this would oversleep
                // the delivery by ~100ms of virtual time.
                g = s2.wait_timeout(m, g, cv, Duration::from_millis(100));
            }
            *woke2.lock().unwrap() = s2.now_ns();
        });
        let (s3, slot3, st3) = (s.clone(), slot.clone(), state.clone());
        let hk = s.spawn("waker", move || {
            s3.sleep(Duration::from_micros(5));
            *st3.0.lock().unwrap() = true;
            let target = s3.now_ns() + 3_000;
            slot3.lock().unwrap().take().unwrap().wake_at(target);
            target
        });
        s.start();
        let target = hk.join().unwrap();
        hw.join().unwrap();
        let woke = *woke_at.lock().unwrap();
        // Never before the delivery timestamp, and exactly at it — the
        // edge, not the 100ms fallback, decided the wake.
        assert_eq!(woke, target, "wake must land exactly on the retime target");
        let snap = s.snapshot();
        assert!(snap.wake_edges >= 1, "edge not counted: {snap:?}");
    }

    #[test]
    fn retime_storms_keep_the_clock_monotone_and_skip_stale_entries() {
        let s = Sched::new(ExecMode::Event);
        let slot: Arc<Mutex<Option<WakeHandle>>> = Arc::new(Mutex::new(None));
        let state: Arc<(Mutex<u32>, Condvar)> = Arc::new((Mutex::new(0), Condvar::new()));
        let stamps = Arc::new(Mutex::new(Vec::new()));
        let (s2, slot2, st2, stamps2) = (s.clone(), slot.clone(), state.clone(), stamps.clone());
        let hw = s.spawn("waiter", move || {
            *slot2.lock().unwrap() = Some(s2.wake_handle().unwrap());
            let (m, cv) = &*st2;
            let mut g = m.lock().unwrap();
            while *g < 4 {
                g = s2.wait_timeout(m, g, cv, Duration::from_secs(1));
                stamps2.lock().unwrap().push(s2.now_ns());
            }
        });
        let (s3, slot3, st3) = (s.clone(), slot.clone(), state.clone());
        let hs = s.spawn("storm", move || {
            for _round in 0..4u32 {
                s3.sleep(Duration::from_micros(50));
                *st3.0.lock().unwrap() += 1;
                let h = slot3.lock().unwrap().clone().unwrap();
                let now = s3.now_ns();
                // A burst per round: a past instant (clamps to now), the
                // real target, and a late duplicate that must go stale
                // once the earliest edge wins the grant.
                h.wake_at(now.saturating_sub(10_000));
                h.wake_at(now + 2_000);
                h.wake_at(now + 900_000);
            }
        });
        s.start();
        hs.join().unwrap();
        hw.join().unwrap();
        let st = stamps.lock().unwrap();
        assert!(
            st.windows(2).all(|w| w[0] <= w[1]),
            "virtual clock rewound under retime storm: {st:?}"
        );
        // Exactly one wake per round: the earliest valid edge wins and
        // the grant's generation bump lazily deletes the other two.
        assert_eq!(st.len(), 4, "stale entries must not re-wake: {st:?}");
        let snap = s.snapshot();
        assert_eq!(snap.wake_edges, 12, "3 retimes per round: {snap:?}");
        assert_eq!(snap.empty_parks, 0, "every wake was an edge: {snap:?}");
    }

    #[test]
    fn point_hook_sees_a_dense_deterministic_ordinal_stream() {
        let run = || {
            let s = Sched::new(ExecMode::Event);
            let seen = Arc::new(Mutex::new(Vec::new()));
            let seen2 = seen.clone();
            s.set_point_hook(move |idx| seen2.lock().unwrap().push(idx));
            let mut handles = Vec::new();
            for id in 0..3usize {
                let s2 = s.clone();
                handles.push(s.spawn(&format!("t{id}"), move || {
                    for _ in 0..4 {
                        s2.sleep(Duration::from_micros(70 + 11 * id as u64));
                    }
                }));
            }
            s.start();
            for h in handles {
                h.join().unwrap();
            }
            let seen = seen.lock().unwrap().clone();
            (seen, s.points())
        };
        let (seen, total) = run();
        // Ordinals are dense: 0, 1, 2, … with no gaps or reordering.
        let want: Vec<u64> = (0..seen.len() as u64).collect();
        assert_eq!(seen, want);
        assert_eq!(total, seen.len() as u64);
        assert!(total >= 12, "each of 12 sleeps parks at least once");
        assert_eq!(run(), (seen, total), "point stream must replay identically");
    }

    #[test]
    fn threaded_mode_exposes_no_schedule_points() {
        let s = Sched::threaded();
        s.set_point_hook(|_| panic!("threaded mode must never park virtually"));
        let h = s.spawn("t", {
            let s2 = s.clone();
            move || s2.sleep(Duration::from_micros(50))
        });
        s.start();
        h.join().unwrap();
        assert_eq!(s.points(), 0);
    }

    #[test]
    fn tasks_spawned_mid_run_get_scheduled() {
        let s = Sched::new(ExecMode::Event);
        let hit = Arc::new(Mutex::new(false));
        let s2 = s.clone();
        let hit2 = hit.clone();
        let h = s.spawn("parent", move || {
            let hit3 = hit2.clone();
            let child = s2.spawn("child", move || {
                *hit3.lock().unwrap() = true;
            });
            // Parent parks; token flows to the child.
            let s3 = s2.clone();
            while !*hit2.lock().unwrap() {
                s3.sleep(Duration::from_micros(100));
            }
            child.join().unwrap();
        });
        s.start();
        h.join().unwrap();
        assert!(*hit.lock().unwrap());
    }
}
