//! Machine-checked safety oracles, shared by the property suites
//! (`rust/tests/prop_invariants.rs`) and the failure-schedule explorer
//! (`crate::explore`) — one implementation of each invariant, so the two
//! suites cannot drift (DESIGN.md §10 property inventory).
//!
//! Every oracle returns `Err(reason)` instead of panicking: the property
//! harness turns that into a failing case with a replay seed, the
//! explorer into a violation carrying a `PARTREPER_SCHEDULE` token.

use std::collections::{HashMap, HashSet};

use crate::obs::Episode;
use crate::partreper::{Channel, Layout, RepairOutcome};

macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
}

/// §V layout invariants after a successful [`Layout::repair`]: the world
/// keeps exactly `ncomp` dense computational slots, no dead rank
/// survives, every replica uniquely mirrors a live comp, promotions and
/// cold restores landed in the slots they claim, and the spare pool is
/// disjoint from the world.
pub fn check_repair_outcome(
    prev: &Layout,
    dead: &HashSet<usize>,
    out: &RepairOutcome,
) -> Result<(), String> {
    let l2 = &out.layout;
    // ncomp is invariant; app ranks stay dense.
    ensure!(l2.ncomp == prev.ncomp, "ncomp changed {} -> {}", prev.ncomp, l2.ncomp);
    ensure!(
        l2.assign.len() == l2.ncomp + l2.nrep(),
        "assign len {} != ncomp {} + nrep {}",
        l2.assign.len(),
        l2.ncomp,
        l2.nrep()
    );
    // No dead fabric rank survives.
    for &f in &l2.assign {
        ensure!(!dead.contains(&f), "dead rank {f} kept in the repaired world");
    }
    // assign has no duplicates.
    let set: HashSet<usize> = l2.assign.iter().copied().collect();
    ensure!(set.len() == l2.assign.len(), "duplicate fabric rank in assign");
    // Every replica mirrors a valid comp rank, uniquely.
    let mut seen = HashSet::new();
    for &m in &l2.rep_mirror {
        ensure!(m < l2.ncomp, "replica mirrors invalid comp {m}");
        ensure!(seen.insert(m), "two replicas of comp {m}");
    }
    // Promotions moved exactly the dead comps with live reps.
    for &(c, f) in &out.promotions {
        ensure!(c < l2.ncomp, "promotion into invalid comp slot {c}");
        ensure!(l2.assign[c] == f, "promotion of comp {c}: rank {f} not in its slot");
    }
    // Cold restores landed on live spares from the old pool.
    for &(c, f) in &out.restores {
        ensure!(c < l2.ncomp, "restore into invalid comp slot {c}");
        ensure!(l2.assign[c] == f, "restore of comp {c}: rank {f} not in its slot");
        ensure!(prev.spares.contains(&f), "restore target {f} was not a spare");
        ensure!(!dead.contains(&f), "restore target {f} is dead");
    }
    // Spare pool: no dead spares kept, none in the world.
    for &s in &l2.spares {
        ensure!(!dead.contains(&s), "dead spare {s} kept in the pool");
        ensure!(!l2.assign.contains(&s), "spare {s} also assigned to the world");
    }
    // epos/rep maps consistent.
    for c in 0..l2.ncomp {
        if let Some(e) = l2.epos(c, Channel::Rep) {
            ensure!(
                l2.rep_mirror[e - l2.ncomp] == c,
                "epos/rep_mirror disagree for comp {c}"
            );
        }
    }
    Ok(())
}

/// Legality of a repair refusal (`Layout::repair -> Err(comp)`):
/// interruption is only allowed when `comp` and its replica (if any) are
/// both dead AND the spare pool cannot cover every unreplicated dead
/// comp — anything else is a recoverable scenario given up on.
pub fn check_interruption_legal(
    prev: &Layout,
    dead: &HashSet<usize>,
    comp: usize,
) -> Result<(), String> {
    ensure!(
        dead.contains(&prev.assign[comp]),
        "interrupted on comp {comp} whose rank is alive"
    );
    if let Some(rf) = prev.rep_fabric_of(comp) {
        ensure!(dead.contains(&rf), "interrupted despite live replica of comp {comp}");
    }
    let live_spares = prev.spares.iter().filter(|f| !dead.contains(f)).count();
    let dead_unrep = (0..prev.ncomp)
        .filter(|&c| {
            dead.contains(&prev.assign[c])
                && prev.rep_fabric_of(c).map_or(true, |rf| dead.contains(&rf))
        })
        .count();
    ensure!(
        live_spares < dead_unrep,
        "interrupted with {live_spares} live spares for {dead_unrep} unreplicated losses"
    );
    Ok(())
}

/// PR 7 observability reconciliation: every error-handler entry produced
/// exactly one episode, per-rank ordinals are dense, each episode's step
/// durations tile its total exactly, and every episode of a rank that
/// ran to completion is itself `completed` with a non-empty pipeline.
pub fn check_episodes(
    episodes: &[Episode],
    handler_entries: u64,
    done_ranks: &[usize],
) -> Result<(), String> {
    ensure!(
        episodes.len() as u64 == handler_entries,
        "{} episodes for {handler_entries} handler entries",
        episodes.len()
    );
    let mut next_seq: HashMap<usize, u64> = HashMap::new();
    for ep in episodes {
        let want = next_seq.entry(ep.rank).or_insert(0);
        ensure!(
            ep.seq == *want,
            "rank {} episode seq {} out of order (want {want})",
            ep.rank,
            ep.seq
        );
        *want += 1;
        let step_sum: u64 = ep.steps.iter().map(|&(_, d)| d).sum();
        ensure!(
            step_sum == ep.total_ns,
            "rank {} episode {}: steps sum {step_sum} != total {}",
            ep.rank,
            ep.seq,
            ep.total_ns
        );
    }
    for &r in done_ranks {
        for ep in episodes.iter().filter(|e| e.rank == r) {
            ensure!(
                ep.completed,
                "rank {r} finished the job but episode {} never completed",
                ep.seq
            );
            ensure!(
                !ep.steps.is_empty(),
                "rank {r} episode {} recorded no pipeline steps",
                ep.seq
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_round(ncomp: usize, nrep: usize, nspares: usize, dead: &[usize]) -> Result<(), String> {
        let layout = Layout::initial_with_spares(ncomp, nrep, nspares);
        let dead: HashSet<usize> = dead.iter().copied().collect();
        match layout.repair(&dead) {
            Ok(out) => check_repair_outcome(&layout, &dead, &out),
            Err(c) => check_interruption_legal(&layout, &dead, c),
        }
    }

    #[test]
    fn real_repairs_pass_the_oracle() {
        one_round(4, 2, 1, &[0]).unwrap(); // promotion
        one_round(4, 2, 1, &[3]).unwrap(); // cold restore onto the spare
        one_round(4, 2, 0, &[3]).unwrap(); // legal interruption
        one_round(4, 4, 0, &[0, 4]).unwrap(); // comp + its replica elsewhere
    }

    #[test]
    fn forged_outcome_is_rejected() {
        let layout = Layout::initial_with_spares(4, 2, 0);
        let dead: HashSet<usize> = [0].into_iter().collect();
        let mut out = layout.repair(&dead).unwrap();
        // Tamper: pretend the dead rank kept its slot.
        out.layout.assign[0] = 0;
        let err = check_repair_outcome(&layout, &dead, &out).unwrap_err();
        assert!(err.contains("dead rank 0"), "{err}");
    }

    #[test]
    fn episode_reconciliation_checks_tiling_and_count() {
        let ep = |rank: usize, seq: u64, steps: Vec<(&'static str, u64)>, completed: bool| {
            Episode {
                rank,
                seq,
                start_ns: 0,
                total_ns: steps.iter().map(|&(_, d)| d).sum(),
                detect_ns: 0,
                trigger: None,
                dead: vec![],
                epoch: 1,
                steps,
                promotions: 0,
                cold_restore: false,
                bytes_resent: 0,
                resends: 0,
                requests_reresolved: 0,
                completed,
            }
        };
        let good = vec![
            ep(0, 0, vec![("revoke", 5), ("repair", 7)], true),
            ep(1, 0, vec![("revoke", 12)], true),
        ];
        check_episodes(&good, 2, &[0, 1]).unwrap();
        // Count mismatch.
        assert!(check_episodes(&good, 3, &[0, 1]).is_err());
        // A done rank with an uncompleted episode.
        let bad = vec![ep(0, 0, vec![("revoke", 5)], false)];
        assert!(check_episodes(&bad, 1, &[0]).is_err());
        // Broken tiling.
        let mut torn = ep(0, 0, vec![("revoke", 5)], true);
        torn.total_ns = 99;
        assert!(check_episodes(&[torn], 1, &[]).is_err());
    }
}
