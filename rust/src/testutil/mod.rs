//! Minimal property-testing helper (the offline image has no `proptest`):
//! PRNG-driven case generation with failing-seed reporting. Used by the
//! `rust/tests/prop_*.rs` suites on coordinator invariants.

pub mod invariants;

use crate::util::Xoshiro256;

/// Run `cases` random trials of `f`, each with a fresh deterministic RNG.
/// On panic, reports the failing case seed so it can be replayed with
/// [`check_one`].
pub fn check(name: &str, cases: usize, f: impl Fn(&mut Xoshiro256) + std::panic::RefUnwindSafe) {
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Xoshiro256::seeded(seed);
            f(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed on case {case} (replay: PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Replay a single failing case.
pub fn check_one(seed: u64, f: impl Fn(&mut Xoshiro256)) {
    let mut rng = Xoshiro256::seeded(seed);
    f(&mut rng);
}

/// Generators.
pub mod gen {
    use crate::util::Xoshiro256;

    /// Uniform usize in [lo, hi].
    pub fn usize_in(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
        lo + rng.next_usize(hi - lo + 1)
    }

    /// Random subset of `0..n` with each element kept at probability `p`.
    pub fn subset(rng: &mut Xoshiro256, n: usize, p: f64) -> Vec<usize> {
        (0..n).filter(|_| rng.next_f64() < p).collect()
    }

    /// Random f32 vector.
    pub fn f32s(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    /// Random bytes.
    pub fn bytes(rng: &mut Xoshiro256, n: usize) -> Vec<u8> {
        (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_when_property_holds() {
        check("addition commutes", 50, |rng| {
            let a = rng.next_u64() >> 1;
            let b = rng.next_u64() >> 1;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn check_reports_failing_seed() {
        check("always fails", 5, |_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn generators_in_range() {
        let mut rng = Xoshiro256::seeded(1);
        for _ in 0..100 {
            let v = gen::usize_in(&mut rng, 3, 7);
            assert!((3..=7).contains(&v));
        }
        let s = gen::subset(&mut rng, 100, 0.5);
        assert!(s.len() > 20 && s.len() < 80);
        assert!(gen::f32s(&mut rng, 10).iter().all(|v| v.abs() <= 1.0));
        assert_eq!(gen::bytes(&mut rng, 16).len(), 16);
    }
}
