//! Little-endian slice codecs for fabric payloads and the process-image
//! serializer. All messages on the simulated wire are `Vec<u8>`; apps and
//! the replication machinery convert typed slices with these helpers.

macro_rules! codec {
    ($to:ident, $from:ident, $ty:ty, $w:expr) => {
        /// Encode a typed slice as little-endian bytes.
        pub fn $to(xs: &[$ty]) -> Vec<u8> {
            let mut out = Vec::with_capacity(xs.len() * $w);
            for x in xs {
                out.extend_from_slice(&x.to_le_bytes());
            }
            out
        }
        /// Decode little-endian bytes back into a typed vector.
        ///
        /// Panics if `bytes.len()` is not a multiple of the element width —
        /// that always indicates a framing bug, never valid data.
        pub fn $from(bytes: &[u8]) -> Vec<$ty> {
            assert!(
                bytes.len() % $w == 0,
                concat!(stringify!($from), ": length {} not a multiple of {}"),
                bytes.len(),
                $w
            );
            bytes
                .chunks_exact($w)
                .map(|c| <$ty>::from_le_bytes(c.try_into().unwrap()))
                .collect()
        }
    };
}

codec!(f64s_to_bytes, f64s_from_bytes, f64, 8);
codec!(f32s_to_bytes, f32s_from_bytes, f32, 4);
codec!(u64s_to_bytes, u64s_from_bytes, u64, 8);
codec!(i64s_to_bytes, i64s_from_bytes, i64, 8);
codec!(u32s_to_bytes, u32s_from_bytes, u32, 4);
codec!(i32s_to_bytes, i32s_from_bytes, i32, 4);

/// A tiny append-only writer used by the process-image serializer.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn bytes(&mut self, v: &[u8]) {
        self.usize(v.len());
        self.buf.extend_from_slice(v);
    }
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Matching reader; all methods panic on truncated input (framing bug).
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> &'a [u8] {
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }
    pub fn u64(&mut self) -> u64 {
        u64::from_le_bytes(self.take(8).try_into().unwrap())
    }
    pub fn usize(&mut self) -> usize {
        self.u64() as usize
    }
    pub fn f64(&mut self) -> f64 {
        f64::from_le_bytes(self.take(8).try_into().unwrap())
    }
    pub fn bytes(&mut self) -> &'a [u8] {
        let n = self.usize();
        self.take(n)
    }
    pub fn str(&mut self) -> String {
        String::from_utf8(self.bytes().to_vec()).expect("utf8")
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// FNV-1a 64-bit, the repo's standard zero-dependency content
/// fingerprint: wire-tap payload hashes and the schedule explorer's
/// run digests both use it, so a digest mismatch and a tap mismatch
/// speak the same language.
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn f64_roundtrip() {
        let xs = vec![0.0, -1.5, f64::MAX, f64::MIN_POSITIVE, 3.141592653589793];
        assert_eq!(f64s_from_bytes(&f64s_to_bytes(&xs)), xs);
    }

    #[test]
    fn u64_roundtrip() {
        let xs = vec![0, 1, u64::MAX, 0xDEADBEEF];
        assert_eq!(u64s_from_bytes(&u64s_to_bytes(&xs)), xs);
    }

    #[test]
    fn i32_roundtrip() {
        let xs = vec![i32::MIN, -1, 0, 1, i32::MAX];
        assert_eq!(i32s_from_bytes(&i32s_to_bytes(&xs)), xs);
    }

    #[test]
    #[should_panic]
    fn misaligned_length_panics() {
        f64s_from_bytes(&[0u8; 9]);
    }

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = ByteWriter::new();
        w.u64(42);
        w.f64(-2.5);
        w.str("hello");
        w.bytes(&[1, 2, 3]);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u64(), 42);
        assert_eq!(r.f64(), -2.5);
        assert_eq!(r.str(), "hello");
        assert_eq!(r.bytes(), &[1, 2, 3]);
        assert_eq!(r.remaining(), 0);
    }
}
