//! Small self-contained utilities: PRNG, statistics, byte codecs.
//!
//! The offline build image has no `rand`/`statrs`/`serde`, so this module
//! provides the minimal, well-tested replacements the rest of the library
//! needs: a splitmix64-seeded xoshiro256** generator, Weibull/exponential
//! sampling, streaming statistics, and little-endian slice codecs used by
//! the fabric payloads and the process-image serializer.

pub mod bytes;
pub mod prng;
pub mod stats;

pub use bytes::fnv1a;
pub use bytes::{f32s_from_bytes, f64s_from_bytes, i64s_from_bytes, u64s_from_bytes};
pub use bytes::{f32s_to_bytes, f64s_to_bytes, i64s_to_bytes, u64s_to_bytes};
pub use prng::Xoshiro256;
pub use stats::Summary;
