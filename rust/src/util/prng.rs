//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Used everywhere randomness is needed (fault-injection timings, workload
//! generation, property tests) so that every experiment is reproducible from
//! a single `u64` seed recorded in the run config.

/// splitmix64 step — used to expand a single seed into the xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via splitmix64 so that nearby seeds give unrelated streams.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent stream for a sub-component (e.g. per-rank).
    pub fn fork(&mut self, salt: u64) -> Self {
        let base = self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
        Self::seeded(base)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough for
    /// our simulation purposes; n is tiny compared to 2^64).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    #[inline]
    pub fn next_usize(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `(0, 1]` — safe to pass through `ln()`.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64_open().ln() / lambda
    }

    /// Weibull with shape `k` and scale `lambda` — the distribution the
    /// paper's fault injector draws inter-failure times from (§VII-B).
    #[inline]
    pub fn weibull(&mut self, shape: f64, scale: f64) -> f64 {
        scale * (-self.next_f64_open().ln()).powf(1.0 / shape)
    }

    /// Standard normal via Marsaglia polar (the same accept/reject scheme the
    /// NPB EP benchmark tallies — see `python/compile/kernels/ep_tally.py`).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let x = 2.0 * self.next_f64() - 1.0;
            let y = 2.0 * self.next_f64() - 1.0;
            let t = x * x + y * y;
            if t > 0.0 && t < 1.0 {
                return x * ((-2.0 * t.ln()) / t).sqrt();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = Xoshiro256::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Xoshiro256::seeded(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Xoshiro256::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weibull_shape1_matches_exponential_mean() {
        // Weibull(k=1, lambda) == Exponential(mean=lambda).
        let mut r = Xoshiro256::seeded(11);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.weibull(1.0, 3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn weibull_shape_below_one_is_heavy_tailed() {
        // k < 1 (the usual HPC failure model): mean = lambda * Gamma(1 + 1/k).
        // For k = 0.7, Gamma(1 + 1/0.7) = Gamma(2.4286) ≈ 1.2658.
        let mut r = Xoshiro256::seeded(13);
        let n = 400_000;
        let mean: f64 = (0..n).map(|_| r.weibull(0.7, 1.0)).sum::<f64>() / n as f64;
        assert!((mean - 1.2658).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::seeded(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Xoshiro256::seeded(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
