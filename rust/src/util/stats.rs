//! Streaming statistics for the benchmark harness (criterion is not
//! available offline, so the bench targets carry their own summaries).

/// Welford streaming mean/variance plus min/max and percentile support.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_samples(samples: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for x in samples {
            s.add(x);
        }
        s
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    /// Raw samples in insertion order (the bench reports re-bucket them
    /// into log2 histograms).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (n-1).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q / 100.0 * (xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            xs[lo]
        } else {
            xs[lo] + (pos - lo as f64) * (xs[hi] - xs[lo])
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Pretty time formatting for bench output.
pub fn fmt_duration_s(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let s = Summary::from_samples([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from_samples((1..=100).map(|i| i as f64));
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        assert!(Summary::new().mean().is_nan());
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration_s(5e-9).contains("ns"));
        assert!(fmt_duration_s(5e-6).contains("µs"));
        assert!(fmt_duration_s(5e-3).contains("ms"));
        assert!(fmt_duration_s(5.0).contains(" s"));
    }
}
