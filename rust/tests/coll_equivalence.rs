//! Equivalence suite for the tuned collective engine: every algorithm
//! variant must produce byte-identical results to the naive rank-order
//! baseline, across comm sizes 1..=17, non-power-of-two payloads, and all
//! `ReduceOp`×`DType` pairs.
//!
//! Reduction inputs are chosen so arithmetic is exact in every dtype
//! (integer-valued, products bounded well under 2^24 for f32): under exact
//! arithmetic, associativity+commutativity make every combining order —
//! tree, recursive doubling, ring reduce-scatter — bit-identical to the
//! sequential rank-order fold.

use std::sync::Arc;
use std::thread;

use partreper::empi::reduce::fold;
use partreper::empi::{coll, Comm, DType, ReduceOp};
use partreper::fabric::{
    AllgatherAlg, AlltoallAlg, AllreduceAlg, BcastAlg, CollTuning, Fabric, NetModel, ProcSet,
    RootedAlg,
};
use partreper::sched::{ExecMode, Sched};
use partreper::util::fnv1a;

/// Run `f(rank, comm)` on `n` threads over a fresh world comm on a fabric
/// with the given model + collective overrides.
fn run_ranks<T: Send + 'static>(
    n: usize,
    model: NetModel,
    coll: CollTuning,
    f: impl Fn(usize, Comm) -> T + Send + Sync + 'static,
) -> Vec<T> {
    let procs = ProcSet::new(n);
    let fabric = Fabric::new_tuned("coll-eq", procs, model, coll);
    let ctx = fabric.alloc_ctx();
    let f = Arc::new(f);
    let handles: Vec<_> = (0..n)
        .map(|r| {
            let fabric = fabric.clone();
            let f = f.clone();
            thread::spawn(move || f(r, Comm::world(fabric, ctx, r)))
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// [`run_ranks`] under the event-driven scheduler: ranks are cooperative
/// tasks dispatched one at a time by the virtual clock, which is what lets
/// these cases scale well past the threaded suite's n=17.
fn run_ranks_event<T: Send + 'static>(
    n: usize,
    model: NetModel,
    coll: CollTuning,
    f: impl Fn(usize, Comm) -> T + Send + Sync + 'static,
) -> Vec<T> {
    let procs = ProcSet::new(n);
    let sched = Sched::new(ExecMode::Event);
    let fabric = Fabric::new_clocked("coll-eq-ev", procs, model, coll, sched.clone());
    let ctx = fabric.alloc_ctx();
    let f = Arc::new(f);
    let handles: Vec<_> = (0..n)
        .map(|r| {
            let fabric = fabric.clone();
            let f = f.clone();
            sched.spawn(&format!("rank-{r}"), move || f(r, Comm::world(fabric, ctx, r)))
        })
        .collect();
    // Nothing runs until the full task set exists.
    sched.start();
    let out = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(
        sched.snapshot().events > 0,
        "event mode must actually schedule"
    );
    out
}

/// Rank `r`'s reduction input: `elems` elements, exact in every dtype.
/// Element `j` is 2 on exactly one rank (`(r + j) % n == 0`) and 1
/// elsewhere, so per element: sum = n+1, prod = 2, min = 1, max = 2 —
/// all exactly representable, any fold order identical.
fn reduce_input(dtype: DType, n: usize, r: usize, elems: usize) -> Vec<u8> {
    let v = |j: usize| -> u64 {
        if (r + j) % n == 0 {
            2
        } else {
            1
        }
    };
    let mut out = Vec::with_capacity(elems * dtype.width());
    for j in 0..elems {
        match dtype {
            DType::F64 => out.extend_from_slice(&(v(j) as f64).to_le_bytes()),
            DType::F32 => out.extend_from_slice(&(v(j) as f32).to_le_bytes()),
            DType::I64 => out.extend_from_slice(&(v(j) as i64).to_le_bytes()),
            DType::U64 => out.extend_from_slice(&v(j).to_le_bytes()),
        }
    }
    out
}

/// The naive baseline: sequential fold over ranks in rank order.
fn naive_reduce(dtype: DType, op: ReduceOp, n: usize, elems: usize) -> Vec<u8> {
    let mut acc = reduce_input(dtype, n, 0, elems);
    for r in 1..n {
        fold(dtype, op, &mut acc, &reduce_input(dtype, n, r, elems));
    }
    acc
}

const ALL_OPS: [ReduceOp; 4] = [ReduceOp::Sum, ReduceOp::Min, ReduceOp::Max, ReduceOp::Prod];
const ALL_DTYPES: [DType; 4] = [DType::F64, DType::F32, DType::I64, DType::U64];

fn allreduce_case(n: usize, alg: AllreduceAlg, dtype: DType, op: ReduceOp, elems: usize) {
    let tuning = CollTuning {
        allreduce: Some(alg),
        ..Default::default()
    };
    let out = run_ranks(n, NetModel::instant(), tuning, move |r, comm| {
        coll::allreduce(&comm, dtype, op, &reduce_input(dtype, n, r, elems)).unwrap()
    });
    let want = naive_reduce(dtype, op, n, elems);
    for (r, got) in out.iter().enumerate() {
        assert_eq!(
            got, &want,
            "allreduce {alg:?} {dtype:?} {op:?} n={n} elems={elems} rank={r}"
        );
    }
}

#[test]
fn allreduce_all_ops_dtypes_match_naive_baseline() {
    // Full op×dtype matrix at representative awkward sizes.
    for alg in [AllreduceAlg::RecursiveDoubling, AllreduceAlg::Ring] {
        for n in [4usize, 5, 16, 17] {
            for dtype in ALL_DTYPES {
                for op in ALL_OPS {
                    allreduce_case(n, alg, dtype, op, 5);
                }
            }
        }
    }
}

#[test]
fn allreduce_every_comm_size_1_to_17() {
    // Every comm size with non-power-of-two payloads (fewer elements than
    // ranks, non-multiples of n, larger than n).
    for alg in [AllreduceAlg::RecursiveDoubling, AllreduceAlg::Ring] {
        for n in 1usize..=17 {
            for elems in [1usize, 5, 33] {
                allreduce_case(n, alg, DType::U64, ReduceOp::Sum, elems);
                allreduce_case(n, alg, DType::F32, ReduceOp::Max, elems);
            }
        }
    }
}

#[test]
fn reduce_matches_naive_baseline() {
    for n in [1usize, 3, 8, 13] {
        for dtype in ALL_DTYPES {
            for op in ALL_OPS {
                let root = n / 2;
                let out = run_ranks(
                    n,
                    NetModel::instant(),
                    CollTuning::default(),
                    move |r, comm| {
                        coll::reduce(&comm, root, dtype, op, &reduce_input(dtype, n, r, 7))
                            .unwrap()
                    },
                );
                let want = naive_reduce(dtype, op, n, 7);
                for (r, got) in out.iter().enumerate() {
                    if r == root {
                        assert_eq!(got.as_ref().unwrap(), &want, "{dtype:?} {op:?} n={n}");
                    } else {
                        assert!(got.is_none());
                    }
                }
            }
        }
    }
}

#[test]
fn bcast_variants_byte_identical() {
    // Chain with several segment sizes (smaller than / dividing / larger
    // than the payload) vs binomial, comm sizes 1..=17.
    for n in 1usize..=17 {
        for (len, seg) in [(0usize, 64usize), (1, 64), (1000, 64), (1000, 1000), (997, 256)] {
            let payload: Vec<u8> = (0..len).map(|i| (i * 31 % 251) as u8).collect();
            for (alg, seg) in [
                (BcastAlg::Binomial, seg),
                (BcastAlg::Chain, seg),
                (BcastAlg::Chain, 7),
            ] {
                let tuning = CollTuning {
                    bcast: Some(alg),
                    bcast_segment: seg,
                    ..Default::default()
                };
                let want = payload.clone();
                let root = (n - 1) / 2;
                let out = run_ranks(n, NetModel::instant(), tuning, move |r, comm| {
                    let mut data = if r == root { want.clone() } else { Vec::new() };
                    coll::bcast(&comm, root, &mut data).unwrap();
                    data
                });
                for (r, got) in out.iter().enumerate() {
                    assert_eq!(got, &payload, "bcast {alg:?} seg={seg} n={n} len={len} r={r}");
                }
            }
        }
    }
}

#[test]
fn allgather_variants_byte_identical() {
    for alg in [AllgatherAlg::Ring, AllgatherAlg::Bruck] {
        let tuning = CollTuning {
            allgather: Some(alg),
            ..Default::default()
        };
        for n in 1usize..=17 {
            for blk in [0usize, 1, 9] {
                let out = run_ranks(n, NetModel::instant(), tuning, move |r, comm| {
                    coll::allgather(&comm, &vec![r as u8; blk]).unwrap()
                });
                for per_rank in &out {
                    assert_eq!(per_rank.len(), n);
                    for (s, b) in per_rank.iter().enumerate() {
                        assert_eq!(b, &vec![s as u8; blk], "allgather {alg:?} n={n} blk={blk}");
                    }
                }
            }
        }
    }
}

#[test]
fn alltoall_variants_byte_identical() {
    for alg in [AlltoallAlg::Pairwise, AlltoallAlg::Bruck] {
        let tuning = CollTuning {
            alltoall: Some(alg),
            ..Default::default()
        };
        for n in 1usize..=17 {
            let out = run_ranks(n, NetModel::instant(), tuning, move |r, comm| {
                // Variable sizes: rank r sends r+d+1 bytes of marker (r, d).
                let blocks: Vec<Vec<u8>> = (0..n)
                    .map(|d| {
                        let mut b = vec![r as u8, d as u8];
                        b.resize(r + d + 2, 0xEE);
                        b
                    })
                    .collect();
                coll::alltoall(&comm, &blocks).unwrap()
            });
            for (r, per_rank) in out.iter().enumerate() {
                for (s, b) in per_rank.iter().enumerate() {
                    let mut want = vec![s as u8, r as u8];
                    want.resize(s + r + 2, 0xEE);
                    assert_eq!(b, &want, "alltoall {alg:?} n={n} r={r} s={s}");
                }
            }
        }
    }
}

#[test]
fn gather_scatter_variants_byte_identical() {
    for alg in [RootedAlg::Linear, RootedAlg::Binomial] {
        let tuning = CollTuning {
            gather: Some(alg),
            scatter: Some(alg),
            ..Default::default()
        };
        for n in 1usize..=17 {
            let root = n / 3;
            // Gather with variable contributions.
            let out = run_ranks(n, NetModel::instant(), tuning, move |r, comm| {
                coll::gather(&comm, root, &vec![r as u8; r % 5 + 1]).unwrap()
            });
            for (r, got) in out.iter().enumerate() {
                if r == root {
                    let bs = got.as_ref().unwrap();
                    for (s, b) in bs.iter().enumerate() {
                        assert_eq!(b, &vec![s as u8; s % 5 + 1], "gather {alg:?} n={n}");
                    }
                } else {
                    assert!(got.is_none());
                }
            }
            // Scatter with variable blocks.
            let out = run_ranks(n, NetModel::instant(), tuning, move |r, comm| {
                let blocks: Option<Vec<Vec<u8>>> =
                    (r == root).then(|| (0..n).map(|d| vec![d as u8; d % 4 + 1]).collect());
                coll::scatter(&comm, root, blocks.as_deref()).unwrap()
            });
            for (r, got) in out.iter().enumerate() {
                assert_eq!(got, &vec![r as u8; r % 4 + 1], "scatter {alg:?} n={n}");
            }
        }
    }
}

#[test]
fn event_mode_allreduce_large_worlds_match_naive_baseline() {
    // Comm sizes far past the threaded suite's 17 — power of two, one past
    // it, and one past 256 — runnable only because event-mode ranks are
    // cooperative tasks, not live OS-thread contenders.
    for (n, alg) in [
        (64usize, AllreduceAlg::RecursiveDoubling),
        (65, AllreduceAlg::Ring),
        (257, AllreduceAlg::RecursiveDoubling),
    ] {
        let tuning = CollTuning {
            allreduce: Some(alg),
            ..Default::default()
        };
        let out = run_ranks_event(n, NetModel::instant(), tuning, move |r, comm| {
            coll::allreduce(
                &comm,
                DType::U64,
                ReduceOp::Sum,
                &reduce_input(DType::U64, n, r, 3),
            )
            .unwrap()
        });
        let want = naive_reduce(DType::U64, ReduceOp::Sum, n, 3);
        for (r, got) in out.iter().enumerate() {
            assert_eq!(got, &want, "event allreduce {alg:?} n={n} rank={r}");
        }
    }
}

#[test]
fn event_mode_bcast_and_allgather_large_worlds() {
    for n in [64usize, 65] {
        let out = run_ranks_event(
            n,
            NetModel::instant(),
            CollTuning {
                allgather: Some(AllgatherAlg::Ring),
                ..Default::default()
            },
            move |r, comm| coll::allgather(&comm, &[r as u8, (n - r) as u8]).unwrap(),
        );
        for per_rank in &out {
            assert_eq!(per_rank.len(), n);
            for (s, b) in per_rank.iter().enumerate() {
                assert_eq!(b, &vec![s as u8, (n - s) as u8], "event allgather n={n}");
            }
        }
    }
    let n = 257usize;
    let payload: Vec<u8> = (0..997).map(|i| (i * 31 % 251) as u8).collect();
    let want = payload.clone();
    let out = run_ranks_event(
        n,
        NetModel::instant(),
        CollTuning {
            bcast: Some(BcastAlg::Binomial),
            ..Default::default()
        },
        move |r, comm| {
            let mut data = if r == 0 { want.clone() } else { Vec::new() };
            coll::bcast(&comm, 0, &mut data).unwrap();
            data
        },
    );
    for (r, got) in out.iter().enumerate() {
        assert_eq!(got, &payload, "event bcast n={n} r={r}");
    }
}

/// [`run_ranks`] with the wire tap armed: returns the canonical per-channel
/// schedule dump alongside the rank results.
fn run_tapped<T: Send + 'static>(
    n: usize,
    coll: CollTuning,
    f: impl Fn(usize, Comm) -> T + Send + Sync + 'static,
) -> (Vec<T>, String) {
    let procs = ProcSet::new(n);
    let fabric = Fabric::new_tuned("coll-tap", procs, NetModel::instant(), coll);
    let ctx = fabric.alloc_ctx();
    fabric.tap_start();
    let f = Arc::new(f);
    let handles: Vec<_> = (0..n)
        .map(|r| {
            let fabric = fabric.clone();
            let f = f.clone();
            thread::spawn(move || f(r, Comm::world(fabric, ctx, r)))
        })
        .collect();
    let out = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (out, fabric.tap_dump())
}

/// [`run_tapped`] under the event scheduler.
fn run_tapped_event<T: Send + 'static>(
    n: usize,
    coll: CollTuning,
    f: impl Fn(usize, Comm) -> T + Send + Sync + 'static,
) -> (Vec<T>, String) {
    let procs = ProcSet::new(n);
    let sched = Sched::new(ExecMode::Event);
    let fabric = Fabric::new_clocked("coll-tap-ev", procs, NetModel::instant(), coll, sched.clone());
    let ctx = fabric.alloc_ctx();
    fabric.tap_start();
    let f = Arc::new(f);
    let handles: Vec<_> = (0..n)
        .map(|r| {
            let fabric = fabric.clone();
            let f = f.clone();
            sched.spawn(&format!("rank-{r}"), move || f(r, Comm::world(fabric, ctx, r)))
        })
        .collect();
    sched.start();
    let out = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (out, fabric.tap_dump())
}

#[test]
fn tap_pins_binomial_bcast_wire_schedule() {
    // Hand-derived schedule for the smallest interesting case: pinned
    // binomial bcast, n=2, 9-byte payload. Exactly one envelope — root to
    // rank 1, the payload itself (the pinned algorithm skips the
    // size-agreement header), on the first collective tag
    // −(BCAST·2³² + 1), send-id 0. If the engine ever grows an extra
    // hop, a header, or a re-pack, this literal breaks.
    let tuning = CollTuning {
        bcast: Some(BcastAlg::Binomial),
        ..Default::default()
    };
    let payload = b"zero-copy".to_vec();
    let want_payload = payload.clone();
    let (outs, dump) = run_tapped(2, tuning, move |r, comm| {
        let mut data = if r == 0 { want_payload.clone() } else { Vec::new() };
        coll::bcast(&comm, 0, &mut data).unwrap();
        data
    });
    assert!(outs.iter().all(|d| d == &payload));
    // The world ctx is the fabric's first allocation; everything else in
    // the line is a pinned literal.
    let want = format!(
        "ctx1 0->1: t-8589934593/s0/l9/h{:016x}\n",
        fnv1a(b"zero-copy")
    );
    assert_eq!(dump, want, "binomial bcast wire schedule drifted");
}

#[test]
fn tap_pins_barrier_wire_schedule() {
    // Dissemination barrier at n=2: one round, each rank sends one empty
    // message to its partner on tag −(BARRIER·2³² + 1). Channels render
    // sorted by (ctx, src, dst).
    let (_, dump) = run_tapped(2, CollTuning::default(), |_r, comm| {
        coll::barrier(&comm).unwrap();
    });
    let h = fnv1a(b"");
    let want = format!(
        "ctx1 0->1: t-4294967297/s0/l0/h{h:016x}\n\
         ctx1 1->0: t-4294967297/s0/l0/h{h:016x}\n"
    );
    assert_eq!(dump, want, "barrier wire schedule drifted");
}

#[test]
fn tap_digest_stable_across_runs_and_modes() {
    // A mixed workload over every collective family, pinned algorithms:
    // the canonical dump must be byte-identical between two independent
    // threaded runs (no hidden timing dependence) and between threaded
    // and event execution (scheduler faithfulness at the EMPI layer, the
    // collective-engine counterpart of the xmode_equivalence suite).
    let tuning = CollTuning {
        bcast: Some(BcastAlg::Chain),
        bcast_segment: 7,
        allgather: Some(AllgatherAlg::Bruck),
        alltoall: Some(AlltoallAlg::Pairwise),
        allreduce: Some(AllreduceAlg::Ring),
        gather: Some(RootedAlg::Binomial),
        ..Default::default()
    };
    let n = 5usize;
    let workload = move |r: usize, comm: Comm| {
        let mut data = if r == 2 {
            (0..23u8).collect::<Vec<u8>>()
        } else {
            Vec::new()
        };
        coll::bcast(&comm, 2, &mut data).unwrap();
        let gathered = coll::allgather(&comm, &vec![r as u8; 3]).unwrap();
        let blocks: Vec<Vec<u8>> = (0..n).map(|d| vec![r as u8, d as u8, 0xEE]).collect();
        let exchanged = coll::alltoall(&comm, &blocks).unwrap();
        let sum = coll::allreduce(
            &comm,
            DType::U64,
            ReduceOp::Sum,
            &reduce_input(DType::U64, n, r, 4),
        )
        .unwrap();
        coll::gather(&comm, 1, &sum).unwrap();
        (data, gathered, exchanged)
    };
    let (out_a, dump_a) = run_tapped(n, tuning, workload);
    let (out_b, dump_b) = run_tapped(n, tuning, workload);
    let (out_e, dump_e) = run_tapped_event(n, tuning, workload);
    assert!(!dump_a.is_empty());
    assert_eq!(out_a, out_b);
    assert_eq!(out_a, out_e);
    assert_eq!(dump_a, dump_b, "threaded wire schedule not reproducible");
    assert_eq!(dump_a, dump_e, "event wire schedule diverged from threaded");
}
    // No overrides, real tuned profile (virtual costs only — inject stays
    // off): payloads straddling the EMPI crossovers must all produce
    // correct results while the engine switches algorithms underneath.
    let model = NetModel::empi_tuned();
    let t = CollTuning::default();
    for n in [5usize, 8] {
        for elems in [8usize, 16 * 1024, 64 * 1024] {
            // Pick sizes on both sides: 64 B, 128 KiB, 512 KiB payloads.
            let bytes = elems * 8;
            let alg = model.select_allreduce(&t, n, bytes);
            let out = run_ranks(n, model, t, move |r, comm| {
                coll::allreduce(
                    &comm,
                    DType::U64,
                    ReduceOp::Sum,
                    &reduce_input(DType::U64, n, r, elems),
                )
                .unwrap()
            });
            let want = naive_reduce(DType::U64, ReduceOp::Sum, n, elems);
            for got in &out {
                assert_eq!(got, &want, "auto allreduce n={n} bytes={bytes} alg={alg:?}");
            }
        }
        // Bcast across its crossover.
        for len in [64usize, 512 * 1024] {
            let payload: Vec<u8> = (0..len).map(|i| (i % 255) as u8).collect();
            let want = payload.clone();
            let out = run_ranks(n, model, t, move |r, comm| {
                let mut data = if r == 0 { want.clone() } else { Vec::new() };
                coll::bcast(&comm, 0, &mut data).unwrap();
                data
            });
            for got in &out {
                assert_eq!(got, &payload, "auto bcast n={n} len={len}");
            }
        }
    }
}
