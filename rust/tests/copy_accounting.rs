//! Copy-accounting golden tests (DESIGN.md §11): the zero-copy payload
//! plumbing is proven safe by *counting*. Every unavoidable send-path
//! materialization is charged through `Fabric::copy_in`/`pack_in` into
//! `FabricMetrics::{payload_copies, payload_copy_bytes}`; these tests pin
//! the exact bill per operation class — a fixed (nranks, algorithm,
//! payload size) matrix at the EMPI level, and differential jobs at the
//! PartRePer level (baseline init+finalize vs. init+ops+finalize with the
//! same config, so the per-op delta isolates the op's own charges). A
//! change that silently reintroduces a copy — or double-charges one —
//! breaks a golden number here, not a benchmark three PRs later.
//!
//! The headline invariant (the paper's zero-copy fan-out, §V-B): one
//! replicated send materializes exactly **one** payload copy per sending
//! incarnation, shared by the MessageLog record and every fan-out
//! envelope — `replicated_isend_fans_out_one_copy_two_envelopes` pins
//! K charges against 2K wire envelopes.

use std::sync::Arc;
use std::thread;

use partreper::config::JobConfig;
use partreper::empi::{coll, Comm, DType, ReduceOp, Src, Tag};
use partreper::error::JobError;
use partreper::fabric::{
    AllgatherAlg, AlltoallAlg, AllreduceAlg, BcastAlg, CollTuning, Envelope, Fabric, MatchSpec,
    NetModel, Payload, ProcSet, RootedAlg,
};
use partreper::partreper::replicate::BlobState;
use partreper::partreper::{PartReper, Start};
use partreper::procmgr::launch_job;

// ------------------------------------------------------------ EMPI level

/// Run `f(rank, comm)` on `n` threads over a fresh instant-model fabric
/// and return the fabric's copy-accounting pair after all ranks join.
fn run_counted<T: Send + 'static>(
    n: usize,
    tuning: CollTuning,
    f: impl Fn(usize, Comm) -> T + Send + Sync + 'static,
) -> (Vec<T>, (u64, u64)) {
    let procs = ProcSet::new(n);
    let fabric = Fabric::new_tuned("copy-acct", procs, NetModel::instant(), tuning);
    let ctx = fabric.alloc_ctx();
    let f = Arc::new(f);
    let handles: Vec<_> = (0..n)
        .map(|r| {
            let fabric = fabric.clone();
            let f = f.clone();
            thread::spawn(move || f(r, Comm::world(fabric, ctx, r)))
        })
        .collect();
    let outs = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let copies = fabric.metrics.copies_snapshot();
    (outs, copies)
}

#[test]
fn eager_fabric_delivery_shares_the_senders_allocation() {
    // The wire itself never materializes: an eager envelope's payload and
    // the delivered envelope's payload are the same allocation, and the
    // fabric charges nothing for moving it.
    let fabric = Fabric::new_tuned(
        "share",
        ProcSet::new(2),
        NetModel::instant(),
        CollTuning::default(),
    );
    let ctx = fabric.alloc_ctx();
    let payload = Payload::from(vec![0xA5u8; 64]);
    fabric
        .send(Envelope::new(0, 1, ctx, 7, 1, payload.clone()))
        .unwrap();
    let env = fabric
        .try_recv(1, &MatchSpec::exact(0, ctx, 7))
        .unwrap()
        .expect("eager envelope is immediately claimable");
    assert!(env.data.shares_buffer(&payload), "delivery copied the payload");
    assert_eq!(env.data, payload);
    assert_eq!(fabric.metrics.copies_snapshot(), (0, 0));
}

#[test]
fn comm_recv_shares_the_senders_payload() {
    // Same property through the EMPI p2p API: `send_payload` on one rank,
    // `recv` on the other — the Recvd's data is a view of the sender's
    // buffer, not a copy, and no charge lands on the fabric.
    let source = Payload::from((0u8..100).collect::<Vec<_>>());
    let sent = source.clone();
    let (outs, copies) = run_counted(2, CollTuning::default(), move |r, comm| {
        if r == 0 {
            comm.send_payload(1, 5, sent.clone()).unwrap();
            None
        } else {
            Some(comm.recv(Src::Rank(0), Tag::Tag(5)).unwrap().data)
        }
    });
    let got = outs[1].as_ref().expect("rank 1 received");
    assert!(got.shares_buffer(&source), "recv materialized a copy");
    assert_eq!(*got, source);
    assert_eq!(copies, (0, 0), "zero-copy path must charge nothing");
}

#[test]
fn blocking_send_charges_exactly_one_copy() {
    // The one unavoidable memcpy: caller-owned bytes entering the runtime.
    let (_, copies) = run_counted(2, CollTuning::default(), |r, comm| {
        if r == 0 {
            comm.send(1, 9, &[0xEE; 100]).unwrap();
        } else {
            comm.recv(Src::Rank(0), Tag::Tag(9)).unwrap();
        }
    });
    assert_eq!(copies, (1, 100));
}

#[test]
fn isend_charges_exactly_one_copy() {
    let (_, copies) = run_counted(2, CollTuning::default(), |r, comm| {
        if r == 0 {
            let req = comm.isend(1, 9, &[0xEE; 64]).unwrap();
            comm.wait_send(&req).unwrap();
        } else {
            comm.recv(Src::Rank(0), Tag::Tag(9)).unwrap();
        }
    });
    assert_eq!(copies, (1, 64));
}

#[test]
fn zero_length_traffic_is_free() {
    // Empty payloads move nothing, so they charge nothing — which is what
    // makes the dissemination barrier (3 rounds at n=8, all empty) bill
    // exactly zero.
    let (_, copies) = run_counted(2, CollTuning::default(), |r, comm| {
        if r == 0 {
            comm.send(1, 1, &[]).unwrap();
        } else {
            comm.recv(Src::Rank(0), Tag::Tag(1)).unwrap();
        }
    });
    assert_eq!(copies, (0, 0));
    let (_, copies) = run_counted(8, CollTuning::default(), |_r, comm| {
        coll::barrier(&comm).unwrap();
    });
    assert_eq!(copies, (0, 0));
}

#[test]
fn bcast_binomial_moves_one_allocation() {
    // Pinned binomial (header skipped): the root materializes one copy;
    // every tree hop forwards a share of the arriving payload.
    let tuning = CollTuning {
        bcast: Some(BcastAlg::Binomial),
        ..Default::default()
    };
    for n in [2usize, 4, 7] {
        let (outs, copies) = run_counted(n, tuning, |r, comm| {
            let mut data = if r == 0 { vec![0xB7; 100] } else { Vec::new() };
            coll::bcast(&comm, 0, &mut data).unwrap();
            data
        });
        assert!(outs.iter().all(|d| d == &vec![0xB7; 100]));
        assert_eq!(copies, (1, 100), "binomial bcast n={n}");
    }
    // Empty broadcast: even the root's copy is free.
    let (_, copies) = run_counted(4, tuning, |_r, comm| {
        let mut data = Vec::new();
        coll::bcast(&comm, 0, &mut data).unwrap();
    });
    assert_eq!(copies, (0, 0));
}

#[test]
fn bcast_chain_charges_root_copy_plus_header() {
    // Pinned chain still runs the size-agreement header (n−1 8-byte hops,
    // each a charged copy of the count); the payload itself is one root
    // copy whose segments travel as zero-copy slices, forwarded unshared
    // by the middle ranks.
    let tuning = CollTuning {
        bcast: Some(BcastAlg::Chain),
        bcast_segment: 256,
        ..Default::default()
    };
    let len = 1000usize;
    let (outs, copies) = run_counted(3, tuning, move |r, comm| {
        let mut data = if r == 0 {
            (0..len).map(|i| (i * 31 % 251) as u8).collect()
        } else {
            Vec::new()
        };
        coll::bcast(&comm, 0, &mut data).unwrap();
        data
    });
    let want: Vec<u8> = (0..len).map(|i| (i * 31 % 251) as u8).collect();
    assert!(outs.iter().all(|d| d == &want));
    // 2 header copies of 8 bytes + 1 root copy of the payload.
    assert_eq!(copies, (3, 16 + len as u64));
}

#[test]
fn allgather_ring_charges_one_copy_per_rank() {
    // Each rank materializes its own block once; the carry then travels
    // the whole ring as that single allocation.
    let tuning = CollTuning {
        allgather: Some(AllgatherAlg::Ring),
        ..Default::default()
    };
    let (_, copies) = run_counted(5, tuning, |r, comm| {
        coll::allgather(&comm, &vec![r as u8; 10]).unwrap()
    });
    assert_eq!(copies, (5, 50));
}

#[test]
fn allgather_bruck_charges_one_pack_per_round() {
    // n=4: every rank packs ⌈log₂ 4⌉ = 2 round buffers. Round 1 ships one
    // block (8-byte count + 8-byte length + blk), round 2 ships two:
    // per-rank bytes 26 + 44 = 70 at blk=10.
    let tuning = CollTuning {
        allgather: Some(AllgatherAlg::Bruck),
        ..Default::default()
    };
    let (_, copies) = run_counted(4, tuning, |r, comm| {
        coll::allgather(&comm, &vec![r as u8; 10]).unwrap()
    });
    assert_eq!(copies, (8, 280));
}

#[test]
fn alltoall_pairwise_charges_each_block_once() {
    let tuning = CollTuning {
        alltoall: Some(AlltoallAlg::Pairwise),
        ..Default::default()
    };
    let (_, copies) = run_counted(4, tuning, |r, comm| {
        let blocks: Vec<Vec<u8>> = (0..4).map(|d| vec![r as u8, d as u8, 0, 0, 0, 0, 0, 0, 0, 0]).collect();
        coll::alltoall(&comm, &blocks).unwrap()
    });
    // n(n−1) = 12 copies of the 10-byte blocks (own block never ships).
    assert_eq!(copies, (12, 120));
}

#[test]
fn alltoall_bruck_charges_one_pack_per_round() {
    // n=4: 2 bit-rounds per rank, each packing two indexed entries —
    // 8 + 2·(8 + 8 + blk) = 60 bytes at blk=10, so 120 per rank.
    let tuning = CollTuning {
        alltoall: Some(AlltoallAlg::Bruck),
        ..Default::default()
    };
    let (_, copies) = run_counted(4, tuning, |r, comm| {
        let blocks: Vec<Vec<u8>> = (0..4).map(|d| vec![r as u8, d as u8, 0, 0, 0, 0, 0, 0, 0, 0]).collect();
        coll::alltoall(&comm, &blocks).unwrap()
    });
    assert_eq!(copies, (8, 480));
}

#[test]
fn gather_and_scatter_charge_counts() {
    // Linear: n−1 direct block copies. Binomial: n−1 packed subtree
    // aggregates — at n=4, root=0, uniform 10-byte blocks the packs are
    // 34 + 34 + 60 = 128 bytes either direction (gather and scatter walk
    // the same tree with the same packing).
    for (alg, want) in [
        (RootedAlg::Linear, (3u64, 30u64)),
        (RootedAlg::Binomial, (3, 128)),
    ] {
        let tuning = CollTuning {
            gather: Some(alg),
            scatter: Some(alg),
            ..Default::default()
        };
        let (_, copies) = run_counted(4, tuning, |r, comm| {
            coll::gather(&comm, 0, &vec![r as u8; 10]).unwrap()
        });
        assert_eq!(copies, want, "gather {alg:?}");
        let (_, copies) = run_counted(4, tuning, |r, comm| {
            let blocks: Option<Vec<Vec<u8>>> =
                (r == 0).then(|| (0..4).map(|d| vec![d as u8; 10]).collect());
            coll::scatter(&comm, 0, blocks.as_deref()).unwrap()
        });
        assert_eq!(copies, want, "scatter {alg:?}");
    }
}

#[test]
fn reduce_charges_one_copy_per_non_root() {
    let (_, copies) = run_counted(4, CollTuning::default(), |r, comm| {
        let data = [(r as u64).to_le_bytes(), 1u64.to_le_bytes()].concat();
        coll::reduce(&comm, 0, DType::U64, ReduceOp::Sum, &data).unwrap()
    });
    // Binomial tree: every rank except the root sends its accumulator
    // exactly once (16 bytes each).
    assert_eq!(copies, (3, 48));
}

#[test]
fn allreduce_rdouble_charges_log_rounds() {
    let tuning = CollTuning {
        allreduce: Some(AllreduceAlg::RecursiveDoubling),
        ..Default::default()
    };
    let (_, copies) = run_counted(4, tuning, |r, comm| {
        let data = [(r as u64).to_le_bytes(), 1u64.to_le_bytes()].concat();
        coll::allreduce(&comm, DType::U64, ReduceOp::Sum, &data).unwrap()
    });
    // Power-of-two world: n ranks × log₂(n) exchanges of the full buffer.
    assert_eq!(copies, (8, 128));
}

#[test]
fn allreduce_ring_charges_two_chunk_passes() {
    let tuning = CollTuning {
        allreduce: Some(AllreduceAlg::Ring),
        ..Default::default()
    };
    let (_, copies) = run_counted(4, tuning, |r, comm| {
        let vals: Vec<u64> = (0..8).map(|j| (r + j) as u64).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        coll::allreduce(&comm, DType::U64, ReduceOp::Sum, &bytes).unwrap()
    });
    // Reduce-scatter + allgather: 2(n−1) hops per rank, each shipping one
    // L/n = 16-byte chunk → 24 copies, 2(n−1)·L = 384 bytes total.
    assert_eq!(copies, (24, 384));
}

// ----------------------------------------- NetModel bill pin (regression)

#[test]
fn chain_bcast_netmodel_bill_is_charged_exactly_once() {
    // Regression pin for the double-charge hazard: receiver-side wire
    // billing plus sender-side `ns_per_byte_copy` could bill a packed
    // segment twice once it crosses the rendezvous threshold. The fix this
    // pins: relays forward shares at zero copy charge, the root charges
    // its payload once, segments are slices of it — so the fabric's entire
    // `virtual_ns` bill is reconstructible as Σ wire_ns_between over the
    // envelope schedule plus Σ copy_ns over the charged copies, nothing
    // else. n=3 pinned chain, 1000 bytes in 256-byte segments, rendezvous
    // at 256 so every full segment is rendezvous-gated.
    let model = NetModel::empi_tuned().with_rndv(256);
    let tuning = CollTuning {
        bcast: Some(BcastAlg::Chain),
        bcast_segment: 256,
        ..Default::default()
    };
    let n = 3usize;
    let len = 1000usize;
    let procs = ProcSet::new(n);
    let fabric = Fabric::new_tuned("bill-pin", procs, model, tuning);
    let ctx = fabric.alloc_ctx();
    let handles: Vec<_> = (0..n)
        .map(|r| {
            let fabric = fabric.clone();
            thread::spawn(move || {
                let comm = Comm::world(fabric, ctx, r);
                let mut data = if r == 0 { vec![0x5C; len] } else { Vec::new() };
                coll::bcast(&comm, 0, &mut data).unwrap();
                assert_eq!(data, vec![0x5C; len]);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // The complete expected envelope schedule: the size-agreement header
    // (root → rank1, root → rank2, 8 bytes each), then four segments
    // (256, 256, 256, 232) each hopping 0→1 and 1→2.
    let mut want_ns: u64 = 0;
    want_ns += model.wire_ns_between(8, n, 0, 1);
    want_ns += model.wire_ns_between(8, n, 0, 2);
    for seg in [256usize, 256, 256, 232] {
        want_ns += model.wire_ns_between(seg, n, 0, 1);
        want_ns += model.wire_ns_between(seg, n, 1, 2);
    }
    // The complete expected copy bill: two 8-byte header copies plus the
    // root's single materialization of the payload. (Each charge is cast
    // to u64 separately, exactly as Fabric::charge_copy does.)
    want_ns += (model.copy_ns(8) as u64) * 2;
    want_ns += model.copy_ns(len) as u64;

    let (messages, wire_bytes, virtual_ns) = fabric.metrics.snapshot();
    assert_eq!(messages, 10, "2 header + 8 segment envelopes");
    assert_eq!(wire_bytes, 16 + 2 * len as u64);
    assert_eq!(fabric.metrics.copies_snapshot(), (3, 16 + len as u64));
    assert_eq!(
        virtual_ns, want_ns,
        "NetModel bill diverged from the envelope schedule + copy charges \
         (a segment was double-charged or a relay charged a copy)"
    );
}

// ------------------------------------------------------- PartRePer level

/// Copies charged on the job's EMPI fabric after running `app` on every
/// incarnation (plus init/start/finalize around it).
fn empi_job_bill(
    cfg: &JobConfig,
    app: impl Fn(&PartReper) + Send + Sync + 'static,
) -> (u64, u64, u64) {
    let report = launch_job(cfg, move |ctx| -> Result<(), JobError> {
        let pr = PartReper::init(ctx);
        if let Start::Retired = pr.start::<BlobState>() {
            return Ok(());
        }
        app(&pr);
        pr.finalize();
        Ok(())
    });
    assert!(
        report.all_done(),
        "job failed: {:?}",
        report.first_error()
    );
    let (copies, bytes) = report.empi_fabric.metrics.copies_snapshot();
    let (messages, _, _) = report.empi_fabric.metrics.snapshot();
    (copies, bytes, messages)
}

/// The differential: charges of init+ops+finalize minus init+finalize with
/// the identical config — init, replication transfer, GC and the finalize
/// barrier cancel, leaving exactly the ops' own bill.
fn job_delta(
    cfg: &JobConfig,
    app: impl Fn(&PartReper) + Send + Sync + 'static,
) -> (u64, u64, u64) {
    let (c0, b0, m0) = empi_job_bill(cfg, |_pr| {});
    let (c1, b1, m1) = empi_job_bill(cfg, app);
    (c1 - c0, b1 - b0, m1 - m0)
}

#[test]
fn replicated_isend_fans_out_one_copy_two_envelopes() {
    // The headline pin: at rdegree=50 the sender (comp 1, unreplicated)
    // fans each send out to comp 0's primary AND replica — two wire
    // envelopes, one charged copy. K sends: K charges, 2K envelopes.
    const K: usize = 4;
    const L: usize = 32;
    let cfg = JobConfig::new(2, 50.0);
    let (copies, bytes, messages) = job_delta(&cfg, |pr| {
        if pr.rank() == 1 {
            let mut reqs: Vec<_> = (0..K)
                .map(|i| pr.isend(0, 100 + i as i64, &[0xC3; L]))
                .collect();
            pr.waitall(&mut reqs);
        } else {
            for i in 0..K {
                assert_eq!(pr.recv(1, 100 + i as i64), vec![0xC3; L]);
            }
        }
    });
    assert_eq!(
        (copies, bytes),
        (K as u64, (K * L) as u64),
        "a replicated send must materialize exactly one copy"
    );
    assert_eq!(messages, 2 * K as u64, "each send fans out to two channels");
}

#[test]
fn full_replication_isend_charges_once_per_incarnation() {
    // rdegree=100: primary and replica both run the app, each charging its
    // own single copy per isend (primary→Comp channel, replica→Rep
    // channel) — so a logical send bills 2 copies and 2 envelopes total,
    // never 3 or 4 (the log record and fan-out tickets share the copy).
    const K: usize = 3;
    const L: usize = 48;
    let mk_app = || {
        |pr: &PartReper| {
            if pr.rank() == 0 {
                let mut reqs: Vec<_> = (0..K)
                    .map(|i| pr.isend(1, 200 + i as i64, &[0x6D; L]))
                    .collect();
                pr.waitall(&mut reqs);
            } else {
                for i in 0..K {
                    assert_eq!(pr.recv(0, 200 + i as i64), vec![0x6D; L]);
                }
            }
        }
    };
    let cfg = JobConfig::new(2, 100.0);
    let (copies, bytes, _) = job_delta(&cfg, mk_app());
    assert_eq!((copies, bytes), ((2 * K) as u64, (2 * K * L) as u64));

    // The serial-fanout ablation routes the same sends through the legacy
    // blocking path — the copy bill must be identical (the ablation varies
    // scheduling, not materialization).
    let mut serial = JobConfig::new(2, 100.0);
    serial.serial_fanout = true;
    let (copies, bytes, _) = job_delta(&serial, |pr| {
        if pr.rank() == 0 {
            for i in 0..K {
                pr.send(1, 200 + i as i64, &[0x6D; L]);
            }
        } else {
            for i in 0..K {
                assert_eq!(pr.recv(0, 200 + i as i64), vec![0x6D; L]);
            }
        }
    });
    assert_eq!((copies, bytes), ((2 * K) as u64, (2 * K * L) as u64));
}

#[test]
fn unreplicated_isend_charges_exactly_one() {
    const K: usize = 5;
    const L: usize = 16;
    let cfg = JobConfig::new(2, 0.0);
    let (copies, bytes, messages) = job_delta(&cfg, |pr| {
        if pr.rank() == 0 {
            let mut reqs: Vec<_> = (0..K)
                .map(|i| pr.isend(1, 300 + i as i64, &[0x11; L]))
                .collect();
            pr.waitall(&mut reqs);
        } else {
            for i in 0..K {
                assert_eq!(pr.recv(0, 300 + i as i64), vec![0x11; L]);
            }
        }
    });
    assert_eq!((copies, bytes), (K as u64, (K * L) as u64));
    assert_eq!(messages, K as u64);
}

#[test]
fn guarded_barrier_bills_only_the_relays() {
    // Barrier carries no payload (all rounds free); the §V-C relay of the
    // Unit result to each primary's replica is the only charge: one 8-byte
    // encode per primary-with-replica.
    let cfg = JobConfig::new(2, 100.0);
    let (copies, bytes, _) = job_delta(&cfg, |pr| {
        pr.barrier();
    });
    assert_eq!((copies, bytes), (2, 16));
}

#[test]
fn guarded_bcast_bill_is_exact() {
    // rdegree=100, ncomp=2, 64-byte payload from root 0. The bill:
    //   wrapper copy_in at each incarnation whose buffer is non-empty
    //     (root primary + root replica): 2 × 64;
    //   auto-selection header on the comp comm (1 hop of 8 bytes);
    //   binomial execution (root's single copy): 1 × 64;
    //   §V-C relays of Flat(64) (16+64 bytes) to both replicas: 2 × 80.
    let cfg = JobConfig::new(2, 100.0);
    let (copies, bytes, _) = job_delta(&cfg, |pr| {
        let mut data = if pr.rank() == 0 { vec![0xF2; 64] } else { Vec::new() };
        pr.bcast(0, &mut data);
        assert_eq!(data, vec![0xF2; 64]);
    });
    assert_eq!((copies, bytes), (6, 360));
}

#[test]
fn store_refresh_bills_snapshot_plus_pushes() {
    // One refresh per comp: 1 charged snapshot encode + 1 charged PushMsg
    // encode per distinct holder. shards=2, redundancy=1 over 3 eligible
    // peers → 2 distinct holders per owner, so 3 charges per comp. The
    // shards themselves are zero-copy slices of the snapshot (split_shards
    // charges nothing).
    let mut cfg = JobConfig::new(4, 0.0);
    cfg.restore.shards = 2;
    cfg.restore.redundancy = 1;
    let (copies, bytes, _) = job_delta(&cfg, |pr| {
        pr.store_refresh(&BlobState(vec![0xCD; 256]));
    });
    assert_eq!(copies, 4 * 3, "per comp: snapshot + 2 holder pushes");
    assert!(bytes > 0);
}
