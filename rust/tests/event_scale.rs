//! Wake-edge determinism at scale (DESIGN.md §8): a 4096-rank bare-EMPI
//! event world — ring exchange, allreduce, one mid-run death noticed
//! off-wire via the failure wake edge, survivor regroup — must be
//! *digest-identical across repeated runs*: same scheduler snapshot
//! (event count, virtual time, wake edges, empty parks), same survivor
//! reductions, same final clock. Retimes are fired while the firing task
//! holds the run token, so each one is a pure function of the schedule
//! prefix; this test is the cross-run half of that argument (the
//! cross-mode half lives in `tests/xmode_equivalence.rs`).

use std::time::Duration;

use partreper::empi::{coll, Comm, DType, ReduceOp, Src, Tag};
use partreper::fabric::{AllreduceAlg, CollTuning, Fabric, NetModel, ProcSet};
use partreper::sched::{ExecMode, Sched, SchedSnapshot};
use partreper::util::{u64s_from_bytes, u64s_to_bytes};

const N: usize = 4096;

struct RunDigest {
    sched: SchedSnapshot,
    final_ns: u64,
    survivor_sums: Vec<u64>,
}

/// One world, same shape as the fig9b scale bench: small stacks keep
/// 4096 threads cheap, and the victim's `wake_all` is the only thing
/// standing between the survivors and a 10 ms fallback park each.
fn run_world() -> RunDigest {
    let tuning = CollTuning {
        // O(log n) rounds; a ring allreduce is O(n) rounds at this scale.
        allreduce: Some(AllreduceAlg::RecursiveDoubling),
        ..Default::default()
    };
    let procs = ProcSet::new(N);
    let sched = Sched::with_stack_bytes(ExecMode::Event, 256 << 10);
    let fabric = Fabric::new_clocked(
        "event-scale",
        procs.clone(),
        NetModel::instant(),
        tuning,
        sched.clone(),
    );
    let world_ctx = fabric.alloc_ctx();
    let repair_ctx = fabric.alloc_ctx();
    let victim = N / 2;
    let handles: Vec<_> = (0..N)
        .map(|r| {
            let fabric = fabric.clone();
            let procs = procs.clone();
            sched.spawn(&format!("rank-{r}"), move || {
                let comm = Comm::world(fabric.clone(), world_ctx, r);
                let mut acc = r as u64 + 1;
                let (right, left) = ((r + 1) % N, (r + N - 1) % N);
                comm.send(right, 1, &acc.to_le_bytes()).unwrap();
                let got = comm.recv(Src::Rank(left), Tag::Tag(1)).unwrap();
                let bytes: [u8; 8] = got.data.as_slice().try_into().unwrap();
                acc = acc.wrapping_add(u64::from_le_bytes(bytes));
                let sum =
                    coll::allreduce(&comm, DType::U64, ReduceOp::Sum, &u64s_to_bytes(&[acc]))
                        .unwrap();
                acc ^= u64s_from_bytes(&sum)[0];
                if r == victim {
                    procs.mark_dead(r);
                    fabric.wake_all();
                    return acc;
                }
                let mut mail = fabric.arrivals(r);
                while !procs.is_dead(victim) {
                    mail = fabric.wait_new_mail(r, mail, Duration::from_micros(500));
                }
                let group: Vec<usize> = (0..N).filter(|&x| x != victim).collect();
                let me = if r < victim { r } else { r - 1 };
                let comm = Comm::from_group(fabric, repair_ctx, group, me);
                let sum =
                    coll::allreduce(&comm, DType::U64, ReduceOp::Sum, &u64s_to_bytes(&[acc]))
                        .unwrap();
                u64s_from_bytes(&sum)[0]
            })
        })
        .collect();
    sched.start();
    let outs: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    RunDigest {
        sched: sched.snapshot(),
        final_ns: sched.now_ns(),
        survivor_sums: outs
            .iter()
            .enumerate()
            .filter(|&(r, _)| r != victim)
            .map(|(_, &v)| v)
            .collect(),
    }
}

#[test]
fn four_k_rank_event_world_is_digest_identical_across_runs() {
    let a = run_world();
    let b = run_world();

    // The run did real work and the wake edges actually fired.
    assert!(a.sched.events > 0);
    assert!(a.sched.advanced_ns > 0);
    assert!(
        a.sched.wake_edges > 0,
        "mail deliveries and the death broadcast must retime parked waiters"
    );
    assert!(
        a.survivor_sums.windows(2).all(|w| w[0] == w[1]),
        "survivors disagree on the post-repair reduction"
    );

    // Determinism: every counter, the virtual clock, and every rank's
    // result replays byte-for-byte.
    assert_eq!(a.sched, b.sched, "scheduler snapshots diverged across runs");
    assert_eq!(a.final_ns, b.final_ns, "virtual clocks diverged");
    assert_eq!(a.survivor_sums, b.survivor_sums, "results diverged");
}
