//! Failure-schedule explorer suite (DESIGN.md §10).
//!
//! The CI smoke sweeps 1000+ distinct schedules over the tiny world and
//! requires every safety property (P1–P5) to hold; the pinned regression
//! tests below replay the two nastiest correlated classes from
//! programmatically-derived `PARTREPER_SCHEDULE` seeds; the self-test
//! proves a violation's printed token reproduces its run byte-identically.
//! Deep multi-shape sweeps (worlds up to n=9) are `#[ignore]`d and run by
//! `ci.sh` under `PARTREPER_EXPLORE_DEEP=1`.

use partreper::config::ExplorePlan;
use partreper::explore::{
    check_run, explore, run_schedule, Injection, Outcome, Scenario, Schedule,
};

/// Probe a scenario's failure-free point space (the coordinate system the
/// pinned seeds below are derived from — fractions of the total, so the
/// seeds survive protocol changes that shift absolute point numbers).
/// Re-derived for the §8 wake-edge engine: parks that used to re-fire
/// every 1 ms of virtual time now mostly resolve on their first edge, so
/// the ordinal stream is shorter and denser in *productive* parks — the
/// same fractions land in the same protocol windows, and the explorer's
/// tokens stay self-describing either way.
fn probe_points(scenario: Scenario) -> u64 {
    let run = run_schedule(&Schedule::probe(scenario));
    check_run(&run).expect("probe must be clean");
    assert!(run.points > 0);
    run.points
}

#[test]
fn ci_smoke_explores_a_thousand_schedules_cleanly() {
    let plan = ExplorePlan::default(); // budget 1200, pinned seed
    let report = explore(Scenario::tiny(), &plan);
    for v in &report.violations {
        eprintln!("PARTREPER_SCHEDULE={}\n  {}", v.token, v.reason);
    }
    assert!(report.ok(), "{} safety violations", report.violations.len());
    assert!(
        report.explored >= 1000,
        "only {} distinct schedules explored (budget {})",
        report.explored,
        plan.budget
    );
    assert!(report.replayed >= 1, "no determinism spot-check ran");
    assert!(report.probe_points > 0);
}

/// Pinned regression: spare death racing its own cold-restore adoption.
/// Kill unreplicated comp (fabric rank 2) a third of the way in, then the
/// only spare (rank 4) eight points later — inside detection/repair of
/// the first death. Whatever the protocol decides (finish the adoption or
/// legally interrupt), every safety property must hold, and the schedule
/// must replay byte-identically.
#[test]
fn pinned_spare_death_mid_adoption() {
    let scenario = Scenario::tiny(); // comps 0..3 (comp 0 replicated), spare 4
    let n = probe_points(scenario);
    let p1 = n / 3;
    let schedule = Schedule {
        scenario,
        injections: vec![
            Injection { point: p1, victim: 2 },
            Injection { point: p1 + 8, victim: 4 },
        ],
    };
    println!("PARTREPER_SCHEDULE={}", schedule.token());
    let run = run_schedule(&schedule);
    check_run(&run).unwrap_or_else(|e| panic!("{e}\ntoken: {}", schedule.token()));
    assert!(
        !run.applied.is_empty(),
        "mid-run kill of comp 2 must land (points {n})"
    );
    let replay = run_schedule(&schedule);
    assert_eq!(replay.digest(), run.digest(), "replay diverged");
}

/// Pinned regression: failures inside GC offer rounds / store pushes.
/// With `gc_interval=2` and `refresh_every=1` the retention gossip and
/// shard-push traffic densely tile the run, so kills at quarter-fractions
/// of the point space land in or adjacent to offer/push windows. Victim 1
/// is unreplicated but a spare exists, forcing the cold-restore path
/// (store offers) through each kill point.
#[test]
fn pinned_failure_in_gc_offer_round() {
    let scenario = Scenario {
        gc_interval: 2,
        ..Scenario::tiny()
    };
    let n = probe_points(scenario);
    for frac in [n / 4, n / 2, 3 * n / 4] {
        let schedule = Schedule {
            scenario,
            injections: vec![Injection { point: frac, victim: 1 }],
        };
        println!("PARTREPER_SCHEDULE={}", schedule.token());
        let run = run_schedule(&schedule);
        check_run(&run).unwrap_or_else(|e| panic!("{e}\ntoken: {}", schedule.token()));
        let replay = run_schedule(&schedule);
        assert_eq!(
            replay.digest(),
            run.digest(),
            "replay diverged at point {frac}"
        );
    }
}

/// Self-test of the violation machinery: forge a wrong observation, check
/// that the oracle flags it, then prove the printed token line reproduces
/// the (real) run byte-identically — the counterexample a violation
/// prints is always actionable.
#[test]
fn injected_violation_reproduces_from_its_printed_token() {
    let schedule = Schedule {
        scenario: Scenario::tiny(),
        injections: vec![Injection { point: 0, victim: 0 }],
    };
    let run = run_schedule(&schedule);
    check_run(&run).expect("the real run is clean");

    let mut forged = run.clone();
    forged.outcomes[2] = Outcome::Done(Some(12345));
    let reason = check_run(&forged).unwrap_err();
    assert!(reason.starts_with("P2"), "{reason}");

    // The exact line explore() prints on a violation.
    let line = format!("PARTREPER_SCHEDULE={}", schedule.token());
    let token = line.strip_prefix("PARTREPER_SCHEDULE=").unwrap();
    let parsed = Schedule::parse(token).unwrap();
    assert_eq!(parsed, schedule);
    let replay = run_schedule(&parsed);
    assert_eq!(replay.render(), run.render(), "token replay not byte-identical");
    assert_eq!(replay.digest(), run.digest());
}

/// Episode reconciliation (satellite: obs cross-check) is live in every
/// explored run: a recovery produces exactly one completed episode whose
/// steps tile its duration, and tearing one step out of a real run's
/// episodes is caught as a P4 violation.
#[test]
fn episode_reconciliation_is_enforced_on_every_run() {
    let schedule = Schedule {
        scenario: Scenario::tiny(),
        injections: vec![Injection { point: 0, victim: 0 }],
    };
    let run = run_schedule(&schedule);
    check_run(&run).unwrap();
    assert!(run.handler_entries >= 1, "recovery must have run");
    assert_eq!(run.episodes.len() as u64, run.handler_entries);

    let mut torn = run.clone();
    let ep = torn
        .episodes
        .iter_mut()
        .find(|e| !e.steps.is_empty())
        .expect("a recovery episode has pipeline steps");
    ep.steps.pop();
    let reason = check_run(&torn).unwrap_err();
    assert!(reason.starts_with("P4"), "{reason}");
}

/// Deep sweep across world shapes up to n=9 (mixed replication degrees
/// and spare counts). Run by `ci.sh` under `PARTREPER_EXPLORE_DEEP=1`:
/// `cargo test -q --test explore_schedules -- --ignored`.
#[test]
#[ignore = "long sweep; enabled by ci.sh under PARTREPER_EXPLORE_DEEP=1"]
fn deep_sweep_across_world_shapes() {
    let shapes = [
        // (ncomp, nrep, nspares) — n = sum, up to 9
        (3, 0, 0),
        (3, 3, 1),
        (4, 2, 2),
        (5, 2, 2),
        (4, 4, 1),
        (6, 2, 1),
    ];
    for (i, &(ncomp, nrep, nspares)) in shapes.iter().enumerate() {
        let scenario = Scenario {
            ncomp,
            nrep,
            nspares,
            iters: 4,
            ..Scenario::tiny()
        };
        // Decorrelate the sampled classes across shapes.
        let plan = ExplorePlan {
            budget: 400,
            seed: ExplorePlan::default().seed.wrapping_add(i as u64),
            ..ExplorePlan::default()
        };
        let report = explore(scenario, &plan);
        for v in &report.violations {
            eprintln!("PARTREPER_SCHEDULE={}\n  {}", v.token, v.reason);
        }
        assert!(
            report.ok(),
            "shape ({ncomp},{nrep},{nspares}): {} violations",
            report.violations.len()
        );
        assert!(report.explored >= 300, "shape ({ncomp},{nrep},{nspares})");
    }
}
