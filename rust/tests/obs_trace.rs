//! Observability determinism (DESIGN.md §9): under `exec.mode=event` the
//! tracer, flight recorder and histogram registry are all fed from the
//! virtual clock, so two runs of the same job — including one injected
//! failure and a replica promotion — must produce *byte-identical* trace
//! and episode exports, and the episode records must reconcile exactly
//! with the protocol counters and phase clocks.
//!
//! The failure choreography is the cross-mode equivalence recipe (see
//! `xmode_equivalence.rs`): quiesce, victim self-poisons, survivors wait
//! off-wire for ULFM knowledge, then run guarded collectives across the
//! promotion.

use std::time::Duration;

use partreper::config::JobConfig;
use partreper::empi::{DType, ReduceOp};
use partreper::error::JobError;
use partreper::metrics::{Counters, Phase};
use partreper::obs::HistId;
use partreper::partreper::replicate::BlobState;
use partreper::partreper::{PartReper, Start};
use partreper::procmgr::{launch_world, JobWorld, RankOutcome};
use partreper::sched::ExecMode;
use partreper::util::{u64s_from_bytes, u64s_to_bytes};

const VICTIM: usize = 0;
const ITERS: u64 = 3;

fn traced_cfg() -> JobConfig {
    let mut cfg = JobConfig::new(5, 50.0);
    cfg.exec = ExecMode::Event;
    cfg.seed = 42;
    cfg.failure_check_stride = 1;
    cfg.obs.trace = true;
    // A short GC cadence so gc_pass spans and GcRound samples appear.
    cfg.log.gc_interval = 4;
    cfg
}

/// Everything one traced run exports and the ground truth to check it
/// against.
struct TracedRun {
    chrome: String,
    episodes_json: String,
    episodes: Vec<partreper::obs::Episode>,
    promotions: u64,
    cold_restores: u64,
    gc_rounds: u64,
    recv_waits: u64,
    gc_round_samples: u64,
    recovery_stalls: u64,
    /// Per-rank `ErrorHandler` / `Restore` / `Replication` phase ns.
    phase_ns: Vec<(u64, u64, u64)>,
    trace_events: u64,
}

fn run_traced() -> TracedRun {
    let cfg = traced_cfg();
    let world = JobWorld::build(&cfg);
    let report = launch_world(world, move |ctx| -> Result<Option<u64>, JobError> {
        let me = ctx.rank;
        let procs = ctx.procs.clone();
        let detector = ctx.detector.clone();
        let clock = ctx.empi_fabric.clock().clone();
        let pr = PartReper::init(ctx);
        match pr.start::<BlobState>() {
            Start::Retired => return Ok(None),
            Start::Fresh => {}
            Start::Restored(_) => {
                return Err(JobError::Runtime("unexpected cold restore".into()));
            }
        }
        let (r, n) = (pr.rank(), pr.size());
        let mut acc: u64 = r as u64 + 1;
        for iter in 0..ITERS {
            let right = (r + 1) % n;
            let left = (r + n - 1) % n;
            let got = pr.sendrecv(right, left, 10 + iter as i64, &acc.to_le_bytes());
            let bytes: [u8; 8] = got.try_into().expect("ring payload is 8 bytes");
            acc = acc.wrapping_add(u64::from_le_bytes(bytes));
            let sum = pr.allreduce(DType::U64, ReduceOp::Sum, &u64s_to_bytes(&[acc]));
            acc ^= u64s_from_bytes(&sum)[0];
        }
        pr.barrier();
        if me == VICTIM {
            procs.poison(me);
            pr.barrier();
            unreachable!("poisoned rank must not survive a fabric op");
        }
        while !detector.is_known_failed(VICTIM) {
            clock.sleep(Duration::from_micros(200));
        }
        let sum = pr.allreduce(DType::U64, ReduceOp::Sum, &u64s_to_bytes(&[acc]));
        acc ^= u64s_from_bytes(&sum)[0];
        pr.finalize();
        Ok(Some(acc))
    });
    let mut killed = 0;
    for o in &report.outcomes {
        match o {
            RankOutcome::Done(_) => {}
            RankOutcome::Killed => killed += 1,
            other => panic!("unexpected outcome {other:?}"),
        }
    }
    assert_eq!(killed, 1, "exactly the victim dies");
    let totals = report.total_counters();
    let phase_ns = report
        .clocks
        .iter()
        .map(|c| {
            (
                c.ns(Phase::ErrorHandler),
                c.ns(Phase::Restore),
                c.ns(Phase::Replication),
            )
        })
        .collect();
    TracedRun {
        chrome: report.obs.chrome_trace_json(),
        episodes_json: report.obs.episodes_json(),
        episodes: report.obs.flight.episodes(),
        promotions: Counters::get(&totals.promotions),
        cold_restores: Counters::get(&totals.cold_restores),
        gc_rounds: Counters::get(&totals.gc_rounds),
        recv_waits: report.obs.hists.get(HistId::RecvWait).count(),
        gc_round_samples: report.obs.hists.get(HistId::GcRound).count(),
        recovery_stalls: report.obs.hists.get(HistId::RecoveryStall).count(),
        phase_ns,
        trace_events: report.obs.tracer.kept(),
    }
}

#[test]
fn event_mode_trace_exports_are_run_to_run_identical() {
    let a = run_traced();
    let b = run_traced();
    assert!(a.trace_events > 0, "tracing was enabled; events must exist");
    assert_eq!(
        a.chrome, b.chrome,
        "event-mode Chrome trace must be byte-identical across runs"
    );
    assert_eq!(
        a.episodes_json, b.episodes_json,
        "event-mode episode export must be byte-identical across runs"
    );
}

#[test]
fn trace_covers_fabric_collective_gc_and_recovery_tracks() {
    let r = run_traced();
    for needle in [
        "\"cat\":\"fabric\"",
        "\"cat\":\"coll\"",
        "\"cat\":\"gc\"",
        "\"cat\":\"req\"",
        "\"cat\":\"ft\"",
        "\"cat\":\"recovery\"",
        "\"pid\":1", // the recovery-episode track
        "\"name\":\"error_handler\"",
    ] {
        assert!(r.chrome.contains(needle), "trace missing {needle}");
    }
    // Both exports parse as single JSON documents line-structured the way
    // the python checker expects.
    assert!(r.chrome.starts_with("[\n") && r.chrome.trim_end().ends_with(']'));
    assert!(r.episodes_json.starts_with("{\"episodes\":["));
}

#[test]
fn episodes_reconcile_with_counters_and_phase_clocks() {
    let r = run_traced();
    assert!(!r.episodes.is_empty(), "the failure must record episodes");

    // Step durations tile each episode exactly.
    for ep in &r.episodes {
        let step_sum: u64 = ep.steps.iter().map(|&(_, d)| d).sum();
        assert_eq!(
            step_sum, ep.total_ns,
            "rank {} seq {}: steps must tile the episode",
            ep.rank, ep.seq
        );
        assert!(ep.completed, "choreographed recovery completes cleanly");
        assert_eq!(ep.dead, vec![VICTIM], "shrink saw exactly the victim");
        assert!(ep.trigger.is_some(), "a failure mark preceded the handler");
    }

    // Episode bookkeeping matches the protocol counters exactly.
    let ep_promotions: u64 = r.episodes.iter().map(|e| e.promotions).sum();
    assert_eq!(ep_promotions, r.promotions);
    assert!(r.promotions >= 1, "rdegree=50 failure promotes a replica");
    let ep_cold: u64 = r.episodes.iter().filter(|e| e.cold_restore).count() as u64;
    assert_eq!(ep_cold, r.cold_restores);

    // One RecoveryStall sample per completed handler entry.
    assert_eq!(r.recovery_stalls, r.episodes.len() as u64);

    // Histograms: every gc_pass recorded a GcRound sample; guarded
    // receives recorded waits.
    assert_eq!(r.gc_round_samples, r.gc_rounds);
    assert!(r.gc_rounds > 0, "gc_interval=4 must run GC passes");
    assert!(r.recv_waits > 0);

    // Under the virtual clock, phase attribution reconciles tick-for-tick:
    // `ErrorHandler` ns accrue only inside handler entries, and a nested
    // `Restore`/`Replication` scope inside an entry suspends them, so per
    // rank: handler <= sum(episode totals) <= handler + restore + repl.
    for (rank, &(handler, restore, repl)) in r.phase_ns.iter().enumerate() {
        let ep_total: u64 = r
            .episodes
            .iter()
            .filter(|e| e.rank == rank)
            .map(|e| e.total_ns)
            .sum();
        assert!(
            ep_total >= handler,
            "rank {rank}: episodes ({ep_total}ns) must cover handler time ({handler}ns)"
        );
        assert!(
            ep_total <= handler + restore + repl,
            "rank {rank}: episodes ({ep_total}ns) exceed handler+restore+replication \
             ({handler}+{restore}+{repl}ns)"
        );
    }
}
