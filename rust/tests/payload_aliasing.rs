//! Aliasing-safety property tests for the zero-copy payload plumbing
//! (DESIGN.md §11): once a send is *posted*, the bytes that travel are the
//! bytes at post time — no matter what the application does to its buffer
//! afterwards, and no matter who else is holding the same `Payload`
//! (message log record, pending replica-channel envelope, unexpected-queue
//! entry, a second receiver of a shared allocation).
//!
//! The runtime's contract has two halves, and each test pins one:
//!  * `&[u8]` entry points (`isend`, `send`) take their single charged
//!    copy at post time — the caller may clobber or drop the buffer the
//!    instant the call returns;
//!  * everything downstream of that copy is a shared immutable `Payload`,
//!    so holding a delivery (or fanning one allocation to many receivers)
//!    can never observe a torn or recycled buffer.
//!
//! Schedules are randomized with a seeded LCG (lengths, receive order)
//! and run under both the threaded and the event-driven scheduler.

use std::sync::Arc;
use std::thread;

use partreper::config::JobConfig;
use partreper::empi::{Comm, Src, Tag};
use partreper::error::JobError;
use partreper::fabric::{CollTuning, Fabric, NetModel, Payload, ProcSet};
use partreper::partreper::replicate::BlobState;
use partreper::partreper::{PartReper, Start};
use partreper::procmgr::launch_job;
use partreper::sched::{ExecMode, Sched};

/// Deterministic pseudo-random bytes: both sides of a channel regenerate
/// the expected payload from (seed, index) instead of shipping oracles.
fn lcg_bytes(seed: u64, len: usize) -> Vec<u8> {
    let mut x = seed ^ 0x9E37_79B9_7F4A_7C15;
    (0..len)
        .map(|_| {
            x = x
                .wrapping_mul(6_364_136_223_846_793_005)
                .wrapping_add(1_442_695_040_888_963_407);
            (x >> 56) as u8
        })
        .collect()
}

/// Message `i`'s length under `seed`: 0..=255 bytes, including the empty
/// edge case, never crossing the tuned rendezvous threshold (so reverse-
/// order receives cannot deadlock on receiver cooperation).
fn msg_len(seed: u64, i: usize) -> usize {
    let mut x = seed.wrapping_add(i as u64).wrapping_mul(0xD134_2543_DE82_EF95);
    x ^= x >> 29;
    (x % 256) as usize
}

const NMSG: usize = 24;

/// Sender half of the property: post `NMSG` isends, clobbering and then
/// dropping each buffer immediately after the post — before any wait and
/// long before delivery is claimed.
fn post_and_clobber(comm: &Comm, dst: usize, seed: u64) {
    let mut reqs = Vec::new();
    for i in 0..NMSG {
        let mut buf = lcg_bytes(seed + i as u64, msg_len(seed, i));
        let req = comm.isend(dst, i as i64, &buf).unwrap();
        // The runtime already took its one charged copy; this buffer is
        // the application's again.
        buf.iter_mut().for_each(|b| *b = 0xDD);
        drop(buf);
        reqs.push(req);
    }
    for req in &reqs {
        comm.wait_send(req).unwrap();
    }
}

/// Receiver half: claim the messages in reverse tag order, so every
/// envelope but the last-posted sits in the unexpected queue while the
/// sender's buffers are already clobbered and freed.
fn recv_reversed(comm: &Comm, src: usize, seed: u64) {
    for i in (0..NMSG).rev() {
        let got = comm.recv(Src::Rank(src), Tag::Tag(i as i64)).unwrap();
        assert_eq!(
            got.data,
            lcg_bytes(seed + i as u64, msg_len(seed, i)),
            "message {i} diverged from its post-time bytes"
        );
    }
}

#[test]
fn isend_buffers_are_free_after_post_threaded() {
    for seed in [3u64, 41, 2026] {
        let procs = ProcSet::new(2);
        let fabric = Fabric::new_tuned(
            "alias-thr",
            procs,
            NetModel::instant(),
            CollTuning::default(),
        );
        let ctx = fabric.alloc_ctx();
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let fabric = fabric.clone();
                thread::spawn(move || {
                    let comm = Comm::world(fabric, ctx, r);
                    if r == 0 {
                        post_and_clobber(&comm, 1, seed);
                    } else {
                        recv_reversed(&comm, 0, seed);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}

#[test]
fn isend_buffers_are_free_after_post_event_mode() {
    for seed in [3u64, 41, 2026] {
        let procs = ProcSet::new(2);
        let sched = Sched::new(ExecMode::Event);
        let fabric = Fabric::new_clocked(
            "alias-ev",
            procs,
            NetModel::instant(),
            CollTuning::default(),
            sched.clone(),
        );
        let ctx = fabric.alloc_ctx();
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let fabric = fabric.clone();
                sched.spawn(&format!("rank-{r}"), move || {
                    let comm = Comm::world(fabric, ctx, r);
                    if r == 0 {
                        post_and_clobber(&comm, 1, seed);
                    } else {
                        recv_reversed(&comm, 0, seed);
                    }
                })
            })
            .collect();
        sched.start();
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            sched.snapshot().events > 0,
            "event mode must actually schedule"
        );
    }
}

#[test]
fn one_allocation_fanned_to_many_receivers_stays_intact() {
    // One Payload, two receivers: both deliveries are views of the same
    // allocation (no per-destination copy), and each receiver holds its
    // view past the sender's exit without observing interference.
    let source = Payload::from(lcg_bytes(77, 4096));
    let expect = source.clone();
    let procs = ProcSet::new(3);
    let fabric = Fabric::new_tuned(
        "alias-fan",
        procs,
        NetModel::instant(),
        CollTuning::default(),
    );
    let ctx = fabric.alloc_ctx();
    let sent = source.clone();
    let handles: Vec<_> = (0..3)
        .map(|r| {
            let fabric = fabric.clone();
            let sent = sent.clone();
            thread::spawn(move || -> Option<Payload> {
                let comm = Comm::world(fabric, ctx, r);
                if r == 0 {
                    comm.send_payload(1, 9, sent.clone()).unwrap();
                    comm.send_payload(2, 9, sent).unwrap();
                    None
                } else {
                    Some(comm.recv(Src::Rank(0), Tag::Tag(9)).unwrap().data)
                }
            })
        })
        .collect();
    let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in [1usize, 2] {
        let held = outs[r].as_ref().expect("receiver returned its payload");
        assert!(held.shares_buffer(&source), "rank {r} got a copy");
        assert_eq!(*held, expect);
    }
    assert_eq!(fabric.metrics.copies_snapshot(), (0, 0));
}

/// The PartRePer-level property: the message-log record and every fan-out
/// channel (primary Comp channel, pending replica channel) hold the
/// post-time bytes, so clobber-after-isend is safe even while replica
/// deliveries are still in flight — under either scheduler and any
/// replication degree.
fn partreper_clobber_job(mode: ExecMode, rdegree: f64, seed: u64) {
    let mut cfg = JobConfig::new(2, rdegree);
    cfg.exec = mode;
    cfg.seed = seed;
    let report = launch_job(&cfg, move |ctx| -> Result<(), JobError> {
        let pr = PartReper::init(ctx);
        if let Start::Retired = pr.start::<BlobState>() {
            return Ok(());
        }
        // Rank 1 sends so that at partial replication (comp 0 replicated,
        // comp 1 not) each post fans out to both of rank 0's incarnations
        // from the single charged copy.
        if pr.rank() == 1 {
            let mut reqs = Vec::new();
            for i in 0..NMSG {
                let mut buf = lcg_bytes(seed + i as u64, msg_len(seed, i));
                let req = pr.isend(0, 500 + i as i64, &buf);
                buf.iter_mut().for_each(|b| *b = 0x00);
                drop(buf);
                reqs.push(req);
            }
            pr.waitall(&mut reqs);
        } else {
            for i in (0..NMSG).rev() {
                assert_eq!(
                    pr.recv(1, 500 + i as i64),
                    lcg_bytes(seed + i as u64, msg_len(seed, i)),
                    "incarnation saw bytes that diverged from post time"
                );
            }
        }
        pr.finalize();
        Ok(())
    });
    assert!(
        report.all_done(),
        "job failed ({mode:?}, rdegree {rdegree}): {:?}",
        report.first_error()
    );
}

#[test]
fn partreper_isend_clobber_threaded() {
    for rdegree in [0.0, 50.0, 100.0] {
        partreper_clobber_job(ExecMode::Threaded, rdegree, 11);
    }
}

#[test]
fn partreper_isend_clobber_event_mode() {
    for rdegree in [0.0, 50.0, 100.0] {
        partreper_clobber_job(ExecMode::Event, rdegree, 11);
    }
}
