//! PJRT integration: load the AOT artifacts and check numerics against the
//! same oracles the Python tests use. Skips (loudly) when `artifacts/` has
//! not been built — `make artifacts` first.

use partreper::runtime::{ComputeEngine, Value};

fn engine() -> Option<ComputeEngine> {
    match ComputeEngine::start(ComputeEngine::default_dir(), 1) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn cg_local_identity_matrix() {
    let Some(eng) = engine() else { return };
    let n = 2048;
    // bands: 9 diagonals, center (index 4) = 2.0, rest 0.
    let mut bands = vec![0f32; 9 * n];
    bands[4 * n..5 * n].fill(2.0);
    let x = vec![1f32; n];
    let offs: Vec<i32> = (-4..=4).collect();
    let out = eng
        .run(
            "cg_local",
            vec![
                Value::f32(bands, &[9, n]),
                Value::f32(x, &[n]),
                Value::i32(offs, &[9]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 3);
    let q = out[0].as_f32();
    assert!(q.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    assert!((out[1].to_scalar_f32() - 2.0 * n as f32).abs() < 1e-1);
    assert!((out[2].to_scalar_f32() - n as f32).abs() < 1e-1);
}

#[test]
fn mg_local_constant_field() {
    let Some(eng) = engine() else { return };
    let u = vec![1f32; 16 * 16 * 16];
    let coeff = vec![-6.0f32, 1.0, 1.0, 1.0];
    let out = eng
        .run(
            "mg_local",
            vec![Value::f32(u, &[16, 16, 16]), Value::f32(coeff, &[4])],
        )
        .unwrap();
    let v = out[0].as_f32();
    // interior of the Laplacian of a constant is 0
    let idx = (8 * 16 + 8) * 16 + 8;
    assert!(v[idx].abs() < 1e-5, "interior {}", v[idx]);
    // residual norm positive (faces feel the zero halo)
    assert!(out[1].to_scalar_f32() > 0.0);
}

#[test]
fn ep_local_acceptance_rate() {
    let Some(eng) = engine() else { return };
    let n = 4096;
    // Low-discrepancy-ish uniforms from a simple LCG.
    let mut s = 12345u64;
    let mut next = || {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((s >> 40) as f32) / (1u32 << 24) as f32
    };
    let u1: Vec<f32> = (0..n).map(|_| next()).collect();
    let u2: Vec<f32> = (0..n).map(|_| next()).collect();
    let out = eng
        .run(
            "ep_local",
            vec![Value::f32(u1, &[n]), Value::f32(u2, &[n])],
        )
        .unwrap();
    let tally = out[0].as_f32();
    let rate = tally[2] / n as f32;
    assert!(
        (rate - std::f32::consts::FRAC_PI_4).abs() < 0.05,
        "acceptance rate {rate}"
    );
}

#[test]
fn is_local_histogram_counts() {
    let Some(eng) = engine() else { return };
    let n = 8192;
    let keys: Vec<i32> = (0..n as i32).map(|i| i % 256).collect();
    let out = eng.run("is_local", vec![Value::i32(keys, &[n])]).unwrap();
    let hist = out[0].as_i32();
    assert_eq!(hist.len(), 256);
    assert!(hist.iter().all(|&c| c == (n / 256) as i32));
}

#[test]
fn cl_local_uniform_state() {
    let Some(eng) = engine() else { return };
    let rho = vec![2.0f32; 32 * 32];
    let e = vec![3.0f32; 32 * 32];
    let out = eng
        .run(
            "cl_local",
            vec![
                Value::f32(rho, &[32, 32]),
                Value::f32(e, &[32, 32]),
                Value::f32(vec![0.01], &[1]),
            ],
        )
        .unwrap();
    let rho2 = out[0].as_f32();
    assert!(rho2.iter().all(|&v| (v - 2.0).abs() < 1e-5));
    // total density conserved
    assert!((out[4].to_scalar_f32() - 2.0 * 1024.0).abs() < 1e-2);
    // energy drops via the work term
    assert!(out[3].to_scalar_f32() < 3.0 * 1024.0);
}

#[test]
fn pic_local_push_and_deposit() {
    let Some(eng) = engine() else { return };
    let n = 4096;
    let pos: Vec<f32> = (0..n).map(|i| (i as f32 * 128.0) / n as f32).collect();
    let vel = vec![0f32; n];
    let ef = vec![1.0f32; 128];
    let out = eng
        .run(
            "pic_local",
            vec![
                Value::f32(pos, &[n]),
                Value::f32(vel, &[n]),
                Value::f32(ef, &[128]),
                Value::f32(vec![0.5], &[1]),
            ],
        )
        .unwrap();
    let vel2 = out[1].as_f32();
    assert!(vel2.iter().all(|&v| (v - 0.5).abs() < 1e-6));
    let rho = out[2].as_f32();
    let total: f32 = rho.iter().sum();
    assert!((total - n as f32).abs() < 0.5, "charge conserved: {total}");
}

#[test]
fn concurrent_ranks_share_engine() {
    let Some(eng) = engine() else { return };
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let eng = eng.clone();
            std::thread::spawn(move || {
                let n = 8192;
                let keys: Vec<i32> = (0..n as i32).map(|i| (i + t) % 256).collect();
                let out = eng.run("is_local", vec![Value::i32(keys, &[n])]).unwrap();
                out[0].as_i32().iter().sum::<i32>()
            })
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), 8192);
    }
}
