//! Property suites on the coordinator's core invariants, driven by the
//! in-repo testutil (proptest is unavailable offline).

use std::collections::HashSet;

use partreper::config::JobConfig;
use partreper::partreper::Layout;
use partreper::procimg::{transfer, ProcessImage};
use partreper::testutil::{check, gen, invariants};

/// One randomized repair scenario at a given world size — shared by the
/// small-world sweep and the large-world (n > 17) cases. The §V oracles
/// themselves live in `testutil::invariants`, shared with the failure-
/// schedule explorer so the two suites check the same algebra.
fn repair_rounds(rng: &mut partreper::util::Xoshiro256, ncomp: usize) {
    let nrep = gen::usize_in(rng, 0, ncomp);
    let nspares = gen::usize_in(rng, 0, 3);
    let mut layout = Layout::initial_with_spares(ncomp, nrep, nspares);
    // Up to 3 failure rounds.
    for _ in 0..gen::usize_in(rng, 1, 3) {
        let world: Vec<usize> = layout.assign.clone();
        let dead: HashSet<usize> = gen::subset(rng, world.len(), 0.25)
            .into_iter()
            .map(|i| world[i])
            .collect();
        match layout.repair(&dead) {
            Ok(out) => {
                invariants::check_repair_outcome(&layout, &dead, &out)
                    .unwrap_or_else(|e| panic!("{e}"));
                layout = out.layout;
            }
            Err(c) => {
                // Interruption is only legal when comp c and its rep
                // (if any) are both dead AND the spare pool could not
                // cover every unreplicated dead comp.
                invariants::check_interruption_legal(&layout, &dead, c)
                    .unwrap_or_else(|e| panic!("{e}"));
                return; // job over for this case
            }
        }
    }
}

/// Layout/repair: for ANY sequence of survivable failures, the repaired
/// layout keeps the §V invariants.
#[test]
fn prop_repair_preserves_layout_invariants() {
    check("repair invariants", 200, |rng| {
        let ncomp = gen::usize_in(rng, 1, 12);
        repair_rounds(rng, ncomp);
    });
}

/// The same §V invariants well past the small-world sweep: the event-mode
/// scale targets (n ∈ {64, 65, 257}) exercise the repair algebra at sizes
/// where dense-rank bookkeeping bugs (off-by-one at powers of two, mirror
/// reindexing) actually show up.
#[test]
fn prop_repair_preserves_layout_invariants_large_worlds() {
    check("repair invariants (large)", 12, |rng| {
        let ncomp = *rng.choose(&[64usize, 65, 257]);
        repair_rounds(rng, ncomp);
    });
}

/// §III-A transfer: for ANY source/target image pair, the replica ends up
/// content-equal to the source (modulo preserved symbols and local
/// addresses) and the repair stats are consistent.
#[test]
fn prop_transfer_makes_replicas() {
    check("transfer replicates", 150, |rng| {
        let mk = |rng: &mut partreper::util::Xoshiro256, preserve: bool| {
            let mut img = ProcessImage::new();
            img.data.define("iter", &rng.next_u64().to_le_bytes());
            img.data.define("handle", &rng.next_u64().to_le_bytes());
            if preserve {
                img.preserve("handle");
            }
            for i in 0..gen::usize_in(rng, 0, 6) {
                let size = gen::usize_in(rng, 1, 512);
                let a = img.heap.alloc(0x100 + i as u64 * 8, size);
                let fill = (rng.next_u64() & 0xFF) as u8;
                img.heap.chunk_mut(a).data.fill(fill);
            }
            let nbytes = gen::usize_in(rng, 0, 256);
            img.stack.bytes = gen::bytes(rng, nbytes);
            img.stack.setjmp(rng.next_u64() % 1000, rng.next_u64() % 8);
            img
        };
        let src = mk(rng, false);
        let mut tgt = mk(rng, true);
        let kept_handle = tgt.data.read("handle").unwrap().to_vec();
        let stats = transfer(&src, &mut tgt);

        // Segment contents equal.
        assert_eq!(tgt.data.len(), src.data.len());
        assert_eq!(tgt.data.read("iter"), src.data.read("iter"));
        assert_eq!(tgt.data.read("handle").unwrap(), kept_handle, "preserved");
        assert_eq!(tgt.heap.nchunks(), src.heap.nchunks());
        for (s, t) in src.heap.chunks().iter().zip(tgt.heap.chunks()) {
            assert_eq!(s.data, t.data);
            assert_eq!(s.ptr_addr, t.ptr_addr);
        }
        assert_eq!(tgt.stack.longjmp(), src.stack.longjmp());
        assert_eq!(stats.heap_bytes, src.heap.total_bytes());
        // Idempotence.
        let snap = tgt.clone();
        transfer(&src, &mut tgt);
        assert_eq!(tgt.heap.chunks(), snap.heap.chunks());
    });
}

/// End-to-end: for ANY replication degree and ANY single survivable kill,
/// the job completes with the failure-free checksum.
#[test]
fn prop_single_survivable_failure_preserves_results() {
    use partreper::apps::AppKind;
    use partreper::harness::{run_app, Backend};

    // Reference checksum, failure-free.
    let cfg0 = JobConfig::new(4, 0.0);
    let want = run_app(&cfg0, AppKind::Ep, Backend::PartReper, 6, None)
        .checksum
        .unwrap();

    check("survivable kill keeps results", 12, |rng| {
        let rdeg = *rng.choose(&[50.0, 100.0]);
        let mut cfg = JobConfig::new(4, rdeg);
        cfg.faults.enabled = true;
        cfg.faults.weibull_shape = 1.0;
        cfg.faults.weibull_scale_s = 0.004;
        cfg.faults.max_failures = 1;
        cfg.faults.seed = rng.next_u64();
        let r = run_app(&cfg, AppKind::Ep, Backend::PartReper, 6, None);
        if r.completed() {
            let got = r.checksum.unwrap();
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "checksum drift after failure: {got} vs {want}"
            );
        } else {
            // Only legal if the injector hit a rank whose twin then also
            // depended on it (double-kill is disabled) OR an unreplicated
            // comp at 50%: victim must have been comp 2..4 without rep.
            assert!(r.was_interrupted(), "errors: {:?}", r.errors);
            assert_eq!(rdeg, 50.0, "100% replication must survive one kill");
        }
    });
}

/// The survivable-kill property under the event-driven scheduler: with
/// 100% replication, ANY single virtual-clock-timed kill still yields the
/// failure-free checksum, and the run reports event-mode scheduling.
#[test]
fn prop_event_mode_survivable_failure_preserves_results() {
    use partreper::apps::AppKind;
    use partreper::harness::{run_app, Backend};
    use partreper::sched::ExecMode;

    // Reference checksum, failure-free, same mode.
    let mut cfg0 = JobConfig::new(4, 0.0);
    cfg0.exec = ExecMode::Event;
    let want = run_app(&cfg0, AppKind::Ep, Backend::PartReper, 6, None)
        .checksum
        .unwrap();

    check("event-mode survivable kill keeps results", 6, |rng| {
        let mut cfg = JobConfig::new(4, 100.0);
        cfg.exec = ExecMode::Event;
        cfg.faults.enabled = true;
        cfg.faults.weibull_shape = 1.0;
        // Virtual milliseconds: parks advance the clock in <=1ms slices,
        // so this lands injections inside the run's virtual lifetime.
        cfg.faults.weibull_scale_s = 0.002;
        cfg.faults.max_failures = 1;
        cfg.faults.seed = rng.next_u64();
        let r = run_app(&cfg, AppKind::Ep, Backend::PartReper, 6, None);
        assert!(
            r.completed(),
            "100% replication must survive one kill: {:?}",
            r.errors
        );
        assert_eq!(r.exec_mode, "event");
        let got = r.checksum.unwrap();
        assert!(
            (got - want).abs() <= 1e-9 * want.abs().max(1.0),
            "checksum drift after event-mode failure: {got} vs {want}"
        );
    });
}

/// Message-log recovery algebra: resend ∪ received covers the full send
/// log; skips never target already-sent ids.
#[test]
fn prop_log_resend_skip_partition() {
    use partreper::partreper::{Channel, IdSet, MessageLog};
    use std::sync::Arc;

    check("resend/skip partition", 200, |rng| {
        let mut log = MessageLog::new();
        let dst = 3;
        let total = gen::usize_in(rng, 0, 40) as u64;
        for i in 0..total {
            log.log_send(dst, 7, Arc::new(vec![i as u8]));
        }
        // Receiver got an arbitrary subset, possibly including "future"
        // ids from a faster twin.
        let future = gen::usize_in(rng, 0, 10) as u64;
        let received_ids: HashSet<u64> = (1..=total + future)
            .filter(|_| rng.next_f64() < 0.6)
            .collect();
        let received: IdSet = received_ids.iter().copied().collect();
        // The compact set is exact.
        for id in 1..=total + future + 1 {
            assert_eq!(received.contains(id), received_ids.contains(&id), "id {id}");
        }
        let resend = log.unreceived_sends(dst, &received);
        let marked = log.mark_future_skips(dst, Channel::Comp, &received);

        // Partition: every sent id is either received or resent.
        let resent: HashSet<u64> = resend.iter().map(|r| r.id).collect();
        for id in 1..=total {
            assert!(
                received.contains(id) || resent.contains(&id),
                "sent id {id} lost"
            );
            assert!(
                !(received.contains(id) && resent.contains(&id)),
                "sent id {id} duplicated"
            );
        }
        // Skips are exactly the received ids beyond my counter.
        let want_skips = received_ids.iter().filter(|&&id| id > total).count();
        assert_eq!(marked, want_skips);
        for id in 1..=total + future {
            let should_skip = id > total && received.contains(id);
            assert_eq!(
                log.consume_skip(dst, Channel::Comp, id),
                should_skip,
                "id {id}"
            );
        }
    });
}

/// Bounded-memory retention (ISSUE 5): under random send/collective/
/// refresh/GC schedules, the agreed floors are monotone and pruning never
/// drops a record that a subsequent recovery — promotion-style (live
/// mirror) or a cold restore from ANY retained store snapshot — still
/// needs: the replay set above the agreed floor stays dense, the
/// stale-store guard never trips, and resend ∪ restored-received covers
/// every send.
#[test]
fn prop_gc_retention_never_drops_needed_records() {
    use partreper::empi::{DType, ReduceOp};
    use partreper::fabric::Payload;
    use partreper::partreper::epoch::agree_floors;
    use partreper::partreper::{CollKind, CollRecord, MessageLog, RetentionOffer, StoreCoverage};
    use std::sync::Arc;

    check("gc retention", 40, |rng| {
        let n = gen::usize_in(rng, 2, 5);
        let mut logs: Vec<MessageLog> = (0..n).map(|_| MessageLog::new()).collect();
        let mut coverages: Vec<StoreCoverage> = (0..n).map(|_| StoreCoverage::new()).collect();
        // Retained restorable snapshots per rank (at most two, oldest
        // first) — the holder-side two-generation rule, modelled as whole
        // log clones taken at the same instant as the coverage marks.
        let mut snaps: Vec<Vec<MessageLog>> = vec![Vec::new(); n];
        let mut inflight: Vec<(usize, usize, u64)> = Vec::new();
        let mut next_coll = 0u64;
        let app_of: Vec<usize> = (0..n).collect();
        // Monotonicity bookkeeping across GC rounds.
        let mut coll_floor_seen = vec![0u64; n];
        let mut send_floor_seen = vec![vec![0u64; n]; n];
        let mut wm_seen = vec![vec![0u64; n]; n];

        for _round in 0..gen::usize_in(rng, 6, 20) {
            for _ in 0..gen::usize_in(rng, 1, 12) {
                match gen::usize_in(rng, 0, 9) {
                    0..=4 => {
                        // Send a -> b; deliver now or leave in flight.
                        let a = gen::usize_in(rng, 0, n - 1);
                        let b = (a + gen::usize_in(rng, 1, n - 1)) % n;
                        let size = gen::usize_in(rng, 1, 16);
                        let id = logs[a].log_send(b, 7, Arc::new(vec![a as u8; size]));
                        if rng.next_f64() < 0.7 {
                            logs[b].log_receive(a, id);
                        } else {
                            inflight.push((a, b, id));
                        }
                    }
                    5 | 6 => {
                        // Deliver a random in-flight message (out of order).
                        if !inflight.is_empty() {
                            let k = gen::usize_in(rng, 0, inflight.len() - 1);
                            let (a, b, id) = inflight.swap_remove(k);
                            logs[b].log_receive(a, id);
                        }
                    }
                    7 | 8 => {
                        // Global collective, logged by every rank.
                        next_coll += 1;
                        for log in logs.iter_mut() {
                            log.log_collective(CollRecord {
                                id: next_coll,
                                kind: CollKind::Allreduce,
                                dtype: DType::U64,
                                op: ReduceOp::Sum,
                                root: 0,
                                input: Payload::from(vec![1, 2, 3]),
                                blocks: Arc::new(vec![]),
                            });
                        }
                    }
                    _ => {
                        // Store refresh for a random rank: snapshot + marks.
                        let r = gen::usize_in(rng, 0, n - 1);
                        snaps[r].push(logs[r].clone());
                        if snaps[r].len() > 2 {
                            snaps[r].remove(0);
                        }
                        coverages[r].on_push(logs[r].snapshot_marks(n));
                    }
                }
            }

            // GC round: every rank offers, agrees floors, prunes.
            let offers: Vec<RetentionOffer> = logs
                .iter()
                .zip(&coverages)
                .map(|(log, cov)| log.retention_offer(n, cov))
                .collect();
            let refs: Vec<Option<&RetentionOffer>> = offers.iter().map(Some).collect();
            for me in 0..n {
                let f = agree_floors(&refs, &app_of, me);
                assert!(f.coll_floor >= coll_floor_seen[me], "coll floor monotone");
                coll_floor_seen[me] = f.coll_floor;
                for d in 0..n {
                    let sf = f.send_floors[&d];
                    assert!(sf >= send_floor_seen[me][d], "send floor monotone");
                    send_floor_seen[me][d] = sf;
                }
                logs[me].prune(f.coll_floor, &f.send_floors);
            }
            for r in 0..n {
                for s in 0..n {
                    let wm = logs[r].receive_watermark(s);
                    assert!(wm >= wm_seen[r][s], "watermarks monotone");
                    wm_seen[r][s] = wm;
                }
            }

            // THE PROPERTY. Fail any rank right now; restore it either as
            // its live mirror (promotion) or from any retained snapshot
            // (cold restore): survivors' pruned logs must still cover it.
            let v = gen::usize_in(rng, 0, n - 1);
            let mut candidates: Vec<MessageLog> = vec![logs[v].clone()];
            candidates.extend(snaps[v].iter().cloned());
            for restored in &candidates {
                let min_cid = logs
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| i != v)
                    .map(|(_, l)| l.last_coll_id())
                    .chain(std::iter::once(restored.last_coll_id()))
                    .min()
                    .unwrap();
                for (i, l) in logs.iter().enumerate() {
                    if i == v {
                        continue;
                    }
                    // Stale-store guard never trips on a GC'd survivor.
                    assert!(
                        l.pruned_to() <= min_cid,
                        "guard would abort: pruned_to {} > min_cid {min_cid}",
                        l.pruned_to()
                    );
                    // Replay completeness: dense above the agreed floor.
                    let got: Vec<u64> =
                        l.collectives_after(min_cid).iter().map(|c| c.id).collect();
                    let want: Vec<u64> = (min_cid + 1..=l.last_coll_id()).collect();
                    assert_eq!(got, want, "replay set of {i} has holes");
                    // Resend completeness toward the restored victim.
                    let have = restored.received_from(i);
                    let resent: HashSet<u64> = l
                        .unreceived_sends(v, &have)
                        .iter()
                        .map(|r| r.id)
                        .collect();
                    for id in 1..=l.sent_up_to(v) {
                        assert!(
                            have.contains(id) || resent.contains(&id),
                            "send {i}->{v} id {id} lost (restored wm {})",
                            have.watermark()
                        );
                    }
                }
            }
        }
    });
}
