//! Cross-mode schedule equivalence: the event-driven scheduler must be a
//! *faithful* execution mode, not merely a plausible one. The anchor
//! (ISSUE 6) is byte-identical **wire schedules**: a job mixing p2p rings,
//! crossover collectives and one replica promotion must enqueue the same
//! messages, in the same per-channel order, with the same payloads, whether
//! the ranks run as preemptive OS threads or as cooperatively scheduled
//! tasks under the virtual clock.
//!
//! The recipe that makes the comparison well-defined in *both* modes:
//!
//! 1. run the mixed workload, then quiesce the wire with a barrier;
//! 2. the victim (fabric rank 0 — a replicated comp under rdegree=50)
//!    self-poisons and dies on its next fabric op, so the failure lands on
//!    an idle fabric;
//! 3. survivors wait **off-wire** (polling the ULFM detector through the
//!    fabric clock) until the failure is known, so the next guarded
//!    collective raises `ProcFailed` *before* any EMPI send on every rank
//!    (`failure_check_stride = 1`), in both modes;
//! 4. the handler's shrink + promotion rebuilds the worlds on
//!    deterministically derived context ids, and the post-repair traffic
//!    is compared byte-for-byte via the fabric's wire tap.
//!
//! Only the EMPI fabric is tapped: OMPI carries detector/consensus control
//! chatter whose volume is legitimately timing-dependent.

use std::time::Duration;

use partreper::config::JobConfig;
use partreper::empi::{DType, ReduceOp};
use partreper::error::JobError;
use partreper::metrics::{Counters, Phase};
use partreper::partreper::replicate::BlobState;
use partreper::partreper::{PartReper, Start};
use partreper::procmgr::{launch_world, JobWorld, RankOutcome};
use partreper::sched::ExecMode;
use partreper::util::{u64s_from_bytes, u64s_to_bytes};

/// Fabric rank 0 is comp 0's primary, which owns a replica whenever
/// nrep >= 1 — dying here exercises the promotion path, not interruption.
const VICTIM: usize = 0;
const ITERS: u64 = 3;

fn job_cfg(ncomp: usize, mode: ExecMode) -> JobConfig {
    let mut cfg = JobConfig::new(ncomp, 50.0);
    cfg.exec = mode;
    cfg.seed = 42;
    // Guard every op: the first post-failure collective must observe the
    // failure before sending, at the same program point in both modes.
    cfg.failure_check_stride = 1;
    cfg
}

/// One mode's observables: the EMPI wire schedule, every survivor's
/// checksum (sorted), the promotion count, and the phase-clock totals
/// (fabric-clock domain: wall under threaded, virtual under event).
struct ModeRun {
    dump: String,
    sums: Vec<u64>,
    promotions: u64,
    /// Copy-accounting pair (`payload_copies`, `payload_copy_bytes`) on the
    /// EMPI fabric: every send-path materialization charges here
    /// (DESIGN.md §11), so cross-mode equality proves the zero-copy
    /// plumbing holds under both schedulers — including across the repair,
    /// whose §VI-B resends re-share logged payloads instead of copying.
    copies: (u64, u64),
    handler_s: f64,
    app_s: f64,
    virtual_s: f64,
    nranks: usize,
}

/// Run the mixed p2p/collective/promotion job under `mode`.
fn schedule_for(ncomp: usize, mode: ExecMode) -> ModeRun {
    let cfg = job_cfg(ncomp, mode);
    let world = JobWorld::build(&cfg);
    world.empi_fabric.tap_start();
    let report = launch_world(world, move |ctx| -> Result<Option<u64>, JobError> {
        // `PartReper::init` consumes the ctx: grab the handles the failure
        // choreography needs first.
        let me = ctx.rank;
        let procs = ctx.procs.clone();
        let detector = ctx.detector.clone();
        let clock = ctx.empi_fabric.clock().clone();
        let pr = PartReper::init(ctx);
        match pr.start::<BlobState>() {
            Start::Retired => return Ok(None),
            Start::Fresh => {}
            Start::Restored(_) => {
                return Err(JobError::Runtime("unexpected cold restore".into()));
            }
        }
        let (r, n) = (pr.rank(), pr.size());
        let mut acc: u64 = r as u64 + 1;
        // Phase 1: p2p ring + crossover collective, repeated.
        for iter in 0..ITERS {
            let right = (r + 1) % n;
            let left = (r + n - 1) % n;
            let got = pr.sendrecv(right, left, 10 + iter as i64, &acc.to_le_bytes());
            let bytes: [u8; 8] = got.try_into().expect("ring payload is 8 bytes");
            acc = acc.wrapping_add(u64::from_le_bytes(bytes));
            let sum = pr.allreduce(DType::U64, ReduceOp::Sum, &u64s_to_bytes(&[acc]));
            acc ^= u64s_from_bytes(&sum)[0];
        }
        // Quiesce so the failure lands on an idle fabric in both modes.
        pr.barrier();
        if me == VICTIM {
            procs.poison(me);
            // The next fabric op notices the poison and unwinds RankKilled
            // before enqueueing anything — no stray tap records.
            pr.barrier();
            unreachable!("poisoned rank must not survive a fabric op");
        }
        // Survivors wait OFF-WIRE until ULFM knows the failure. The wait
        // must tick through the fabric clock: under event mode a raw
        // std::thread::sleep would stall the whole virtual world.
        while !detector.is_known_failed(VICTIM) {
            clock.sleep(Duration::from_micros(200));
        }
        // Phase 2: guarded collectives across the promotion.
        let sum = pr.allreduce(DType::U64, ReduceOp::Sum, &u64s_to_bytes(&[acc]));
        acc ^= u64s_from_bytes(&sum)[0];
        let root = 1 % n;
        let mut blob = u64s_to_bytes(&[if r == root { acc } else { 0 }]);
        pr.bcast(root, &mut blob);
        acc ^= u64s_from_bytes(&blob)[0];
        pr.finalize();
        Ok(Some(acc))
    });
    let mut sums = Vec::new();
    let mut killed = 0;
    for o in &report.outcomes {
        match o {
            RankOutcome::Done(Some(v)) => sums.push(*v),
            RankOutcome::Done(None) => {}
            RankOutcome::Killed => killed += 1,
            other => panic!("{mode:?} ncomp={ncomp}: unexpected outcome {other:?}"),
        }
    }
    assert_eq!(killed, 1, "{mode:?} ncomp={ncomp}: exactly the victim dies");
    sums.sort_unstable();
    let promotions = Counters::get(&report.total_counters().promotions);
    let virtual_ns = report.empi_fabric.clock().snapshot().advanced_ns;
    ModeRun {
        dump: report.empi_fabric.tap_dump(),
        sums,
        promotions,
        copies: report.empi_fabric.metrics.copies_snapshot(),
        handler_s: report.phase_seconds(Phase::ErrorHandler),
        app_s: report.phase_seconds(Phase::App),
        virtual_s: virtual_ns as f64 / 1e9,
        nranks: report.outcomes.len(),
    }
}

fn assert_modes_agree(ncomp: usize) {
    let t = schedule_for(ncomp, ExecMode::Threaded);
    let e = schedule_for(ncomp, ExecMode::Event);
    assert!(t.promotions >= 1, "threaded ncomp={ncomp}: promotion must fire");
    assert!(e.promotions >= 1, "event ncomp={ncomp}: promotion must fire");
    assert!(!t.dump.is_empty(), "tap must have captured EMPI traffic");
    assert_eq!(
        t.sums, e.sums,
        "ncomp={ncomp}: survivor checksums diverged across modes"
    );
    assert_eq!(
        t.dump, e.dump,
        "ncomp={ncomp}: wire schedules diverged across modes"
    );
    // The copy bill must agree too: same schedule, same materializations.
    // A scheduler-dependent copy (e.g. a repair path that clones instead
    // of sharing under one mode's interleaving) diverges here.
    assert!(t.copies.0 > 0, "the workload must charge some copies");
    assert_eq!(
        t.copies, e.copies,
        "ncomp={ncomp}: copy accounting diverged across modes"
    );
    // Phase attribution must work in both clock domains: every run spends
    // real time in the app and error-handler phases.
    assert!(t.handler_s > 0.0, "threaded ncomp={ncomp}: handler phase empty");
    assert!(e.handler_s > 0.0, "event ncomp={ncomp}: handler phase empty");
    assert!(t.app_s > 0.0 && e.app_s > 0.0);
    // And in event mode it must be *virtual* time: per rank, attributed
    // time cannot exceed the job's total virtual span. (With the old
    // wall-clock PhaseClock this sum was host wall time — orders of
    // magnitude past the virtual span.)
    assert!(
        e.app_s + e.handler_s <= e.nranks as f64 * e.virtual_s + 1e-9,
        "ncomp={ncomp}: event-mode phase totals exceed the virtual span \
         (app={} + handler={} > {} ranks x {}s)",
        e.app_s,
        e.handler_s,
        e.nranks,
        e.virtual_s
    );
}

#[test]
fn wire_schedule_identical_across_modes_n5() {
    assert_modes_agree(5);
}

#[test]
fn wire_schedule_identical_across_modes_n9() {
    assert_modes_agree(9);
}

#[test]
fn wire_schedule_identical_across_modes_n17() {
    assert_modes_agree(17);
}

/// Promotion mid-waitall, cross-mode: every rank posts a full batch of
/// isends + irecvs, then comp 1's primary dies with the batch outstanding.
/// Pending requests ride the repair (receives re-resolve to the promoted
/// incarnation, sends re-issue per channel) and the §VI-B resends re-share
/// the original logged allocations — so although *how many* requests are
/// pending at the failure instant is scheduler-dependent, the copy bill is
/// not: re-issues and resends charge nothing, leaving only the
/// deterministic post-time and repair-protocol charges, identical across
/// modes.
fn waitall_promotion_run(mode: ExecMode) -> ((u64, u64), u64, u64) {
    let mut cfg = JobConfig::new(4, 100.0);
    cfg.exec = mode;
    cfg.seed = 42;
    let iters = 8u64;
    let report = launch_world(JobWorld::build(&cfg), move |ctx| -> Result<Option<u64>, JobError> {
        let rank = ctx.rank;
        let procs = ctx.procs.clone();
        let pr = PartReper::init(ctx);
        if let Start::Retired = pr.start::<BlobState>() {
            return Ok(None);
        }
        let n = pr.size();
        let me = pr.rank();
        let mut sum = 0u64;
        for it in 0..iters {
            let mut reqs = Vec::new();
            let mut sources = Vec::new();
            for other in 0..n {
                if other != me {
                    reqs.push(pr.irecv(other, 11));
                    sources.push(other);
                }
            }
            for other in 0..n {
                if other != me {
                    reqs.push(pr.isend(other, 11, &u64s_to_bytes(&[(me as u64) << 32 | it])));
                }
            }
            if rank == 1 && it == 4 {
                // Die with the whole batch outstanding: waitall is the
                // next fabric op, so the batch crosses the promotion.
                procs.poison(1);
            }
            pr.waitall(&mut reqs);
            for (slot, &src) in sources.iter().enumerate() {
                let v = u64s_from_bytes(&reqs[slot].take_data().expect("recv payload"))[0];
                assert_eq!(v, (src as u64) << 32 | it, "round {it} from {src}");
                sum = sum.wrapping_add(v);
            }
        }
        pr.finalize();
        Ok(Some(sum))
    });
    let expect_for = |k: u64| -> u64 {
        (0..iters)
            .flat_map(|it| (0..4u64).filter(move |&o| o != k).map(move |o| o << 32 | it))
            .fold(0u64, u64::wrapping_add)
    };
    let mut done = 0;
    let mut killed = 0;
    for (r, o) in report.outcomes.iter().enumerate() {
        match (r, o) {
            (1, RankOutcome::Killed) => killed += 1,
            (_, RankOutcome::Done(Some(v))) => {
                done += 1;
                assert_eq!(*v, expect_for((r % 4) as u64), "{mode:?} rank {r}");
            }
            (_, RankOutcome::Done(None)) => {}
            (_, other) => panic!("{mode:?} rank {r}: {other:?}"),
        }
    }
    assert_eq!((killed, done), (1, 7), "{mode:?}: one victim, seven finishers");
    let totals = report.total_counters();
    (
        report.empi_fabric.metrics.copies_snapshot(),
        Counters::get(&totals.promotions),
        Counters::get(&totals.nb_replays),
    )
}

#[test]
fn promotion_mid_waitall_copy_bill_identical_across_modes() {
    let (t_copies, t_promotions, t_replays) = waitall_promotion_run(ExecMode::Threaded);
    let (e_copies, e_promotions, e_replays) = waitall_promotion_run(ExecMode::Event);
    assert_eq!(t_promotions, 1, "threaded: exactly one promotion");
    assert_eq!(e_promotions, 1, "event: exactly one promotion");
    assert!(t_replays > 0, "threaded: pending requests must ride the repair");
    assert!(e_replays > 0, "event: pending requests must ride the repair");
    assert!(t_copies.0 > 0);
    assert_eq!(
        t_copies, e_copies,
        "a scheduler-dependent number of requests crossed the promotion, \
         yet re-issued sends materialized copies (they must re-share the \
         original allocations)"
    );
}
